"""Speculative metadata-prefetch pipeline tests (PR 5).

Covers the pipelined cold walk (batched ``readdir_plus_vec``, fewer
backend roundtrips than directories), per-*fused*-batch fault gating and
its advisory semantics (no ledger entry, no poison, per-directory
fallback), racing-mutation ticket invalidation (deterministic via a
gateable vectored backend, plus an 8-worker hammer with stealing on and
off), the LRU-cold insertion rule for speculative listings (prefetch can
never demote the hot in-use window), adaptive batch sizing from the
latency backend's measured BDP, and the PR 4 known-gap regression: a
rename must wait for non-structural ops on paths with no pending
structural anchor (chmod of a pre-window file three levels down)."""
import threading
import time
from collections import Counter

import pytest

from repro.core import (CannyFS, EagerFlags, FaultInjectingBackend,
                        FaultPlan, FaultRule, InMemoryBackend,
                        LatencyBackend, LatencyModel, NamespaceOverlay,
                        OverlayPolicy, PrefetchPolicy, VirtualClock)

BOUNDARY_OPS = frozenset({
    "mkdir", "rmdir", "create", "unlink", "rename", "symlink", "link",
    "readlink", "write_at", "write_vec", "read_at", "truncate", "fallocate",
    "fsync", "chmod", "chown", "utimens", "setxattr", "removexattr", "stat",
    "readdir", "readdir_plus", "readdir_plus_vec", "remove_tree",
})


class Boundary:
    """Counts ops the *engine* issues; inner-loop calls stay invisible."""

    def __init__(self, inner):
        self.inner = inner
        self.counts = Counter()

    def __getattr__(self, name):
        attr = getattr(self.inner, name)
        if name in BOUNDARY_OPS:
            def wrap(*a, **k):
                self.counts[name] += 1
                return attr(*a, **k)
            return wrap
        return attr


class VecGate(InMemoryBackend):
    """Blocks every vectored speculative fetch on a gate so racing
    mutations can be admitted deterministically while the batch is in
    flight."""

    def __init__(self):
        super().__init__()
        self.gate = threading.Event()
        self.entered = threading.Event()

    def readdir_plus_vec(self, paths):
        self.entered.set()
        self.gate.wait()
        return super().readdir_plus_vec(paths)


def build_cold_tree(backend, n_subdirs=6, files_per_dir=2, root="cold",
                    grandchildren=0):
    """A tree the mount has never observed, directly on the backend."""
    dirs = [root] + [f"{root}/d{i}" for i in range(n_subdirs)]
    for i in range(grandchildren):
        dirs.append(f"{root}/d0/g{i}")
    for d in dirs:
        backend.mkdir(d)
    for d in dirs:
        for j in range(files_per_dir):
            backend.create(f"{d}/f{j}")
    return dirs


# ---------------------------------------------------------------------------
# the pipelined cold walk
# ---------------------------------------------------------------------------

def test_cold_walk_costs_fewer_roundtrips_than_dirs():
    """The tentpole: a cold walk's metadata no longer costs one roundtrip
    per directory — discovered subdirectories are fetched in batched
    speculative reads ahead of the consumer."""
    inner = InMemoryBackend()
    dirs = build_cold_tree(inner, n_subdirs=8, grandchildren=4)
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=5.0, data_ms=5.0, jitter_sigma=0.0,
                            seed=1))
    fs = CannyFS(remote, workers=8, echo_errors=False)
    walked = {d: (tuple(s), tuple(f)) for d, s, f in fs.walk("cold")}
    fs.close()
    st = fs.stats
    assert set(walked) == set(dirs)              # complete, nothing skipped
    assert walked["cold"][0] == tuple(f"d{i}" for i in range(8))
    assert walked["cold/d0"][0] == tuple(f"g{i}" for i in range(4))
    for d in dirs:
        assert walked[d][1] == ("f0", "f1")
    assert st.prefetch_batches >= 1
    assert st.prefetch_hits >= 1
    assert remote.op_count < len(dirs), (remote.op_count, len(dirs))
    assert len(fs.ledger) == 0


def test_cold_readdir_seeds_children_one_vec_call_per_batch():
    """One frontier batch is ONE backend call: the vectored
    readdir_plus_vec covers every discovered subdirectory."""
    inner = InMemoryBackend()
    build_cold_tree(inner, n_subdirs=5, files_per_dir=1)
    be = Boundary(inner)
    fs = CannyFS(be, workers=4, echo_errors=False)
    assert fs.readdir("cold") == sorted(
        [f"d{i}" for i in range(5)] + ["f0"])
    fs.engine._sched.drain()     # let the batch land without quiescing
    st = fs.stats
    assert be.counts["readdir_plus"] == 1        # the cold miss
    assert be.counts["readdir_plus_vec"] == 1    # ONE fused call, 5 dirs
    assert st.prefetch_batches == 1
    assert st.prefetch_issued == 5
    # every subdir is now overlay-complete: readdirs are hits, no backend
    for i in range(5):
        assert fs.readdir(f"cold/d{i}") == ["f0"]
    assert be.counts["readdir_plus"] == 1
    assert st.prefetch_hits == 5
    # ...and the listings warmed the stat cache
    assert be.counts["stat"] == 0
    assert fs.stat("cold/d3/f0").exists
    assert be.counts["stat"] == 0
    fs.close()


def test_prefetch_off_restores_per_directory_walk():
    inner = InMemoryBackend()
    dirs = build_cold_tree(inner, n_subdirs=4)
    be = Boundary(inner)
    fs = CannyFS(be, echo_errors=False, prefetch=False)
    assert fs.engine.prefetcher is None
    walked = list(fs.walk("cold"))
    fs.close()
    assert len(walked) == len(dirs)
    assert be.counts["readdir_plus_vec"] == 0
    assert be.counts["readdir_plus"] == len(dirs)   # one sync miss per dir
    assert fs.stats.prefetch_batches == 0


def test_overlay_off_disables_prefetcher():
    fs = CannyFS(InMemoryBackend(), overlay=False, echo_errors=False,
                 workers=2)
    assert fs.engine.prefetcher is None
    fs.close()


def test_speculative_reads_never_seal_pending_chains():
    """A speculative listing is not an observation: elision under the
    prefetched tree still fires afterwards."""
    inner = InMemoryBackend()
    build_cold_tree(inner, n_subdirs=2, files_per_dir=0)
    fs = CannyFS(inner, workers=4, echo_errors=False)
    fs.readdir("cold")               # miss -> seeds cold/d0, cold/d1
    fs.engine._sched.drain()
    assert fs.stats.prefetch_issued == 2
    # write+unlink in the same window under a *prefetched* dir: the
    # chain elides exactly as it would without prefetch
    fs.write_file("cold/d0/tmp", b"x" * 64)
    fs.unlink("cold/d0/tmp")
    assert fs.stats.elided_ops >= 2
    assert fs.stats.bytes_elided >= 64
    fs.drain()
    assert "cold/d0/tmp" not in inner.snapshot()["files"]
    assert len(fs.ledger) == 0
    fs.close()


# ---------------------------------------------------------------------------
# faults: per-fused-batch gating, strictly advisory
# ---------------------------------------------------------------------------

def test_fault_fires_once_per_fused_batch_and_stays_advisory():
    """A FaultRule matching the vectored batch fires ONCE for the whole
    fused call (not once per directory), nothing lands in the ledger, the
    engine is not poisoned even with abort_on_error, and the walk falls
    back per-directory to the correct answer."""
    inner = InMemoryBackend()
    dirs = build_cold_tree(inner, n_subdirs=6, files_per_dir=1)
    # match 1 = the cold sync readdir_plus of "cold"; match 2 = the one
    # fused batch (6 dirs, still a single match); later sync fallbacks
    # find max_failures exhausted
    plan = FaultPlan([FaultRule(error="EIO", ops=("readdir",),
                                after_count=1, max_failures=1)])
    fs = CannyFS(FaultInjectingBackend(inner, plan), workers=4,
                 echo_errors=False, abort_on_error=True)
    assert "d0" in fs.readdir("cold")
    fs.engine._sched.drain()          # the faulted batch lands (dropped)
    assert plan.injected == 1                     # ONE match for 6 dirs
    assert plan.fire_counts[0] == 1
    assert fs.stats.prefetch_wasted == 6          # the whole batch dropped
    assert not fs.poisoned                        # advisory: no poison
    assert len(fs.ledger) == 0                    # ...and no ledger entry
    # nothing speculative was installed: the walk falls back per-dir
    ov = fs.engine.overlay
    for i in range(6):
        assert ov.readdir(f"cold/d{i}") is None
    walked = {d for d, _, _ in fs.walk("cold")}
    assert walked == set(dirs)
    fs.close()


def test_injected_faults_on_real_ops_still_ledger_with_prefetch_on():
    """Prefetch must not absorb real ops' faults: a write fault under a
    prefetched tree defers to the ledger exactly as before."""
    inner = InMemoryBackend()
    build_cold_tree(inner, n_subdirs=2, files_per_dir=0)
    plan = FaultPlan([FaultRule(error="EIO", ops=("write",),
                                path_glob="cold/d0/*")])
    fs = CannyFS(FaultInjectingBackend(inner, plan), workers=4,
                 echo_errors=False)
    fs.readdir("cold")
    fs.engine._sched.drain()
    fs.write_file("cold/d0/out", b"x")
    fs.drain()
    assert plan.injected == 1
    assert fs.stats.deferred_errors == 1
    assert len(fs.ledger) == 1
    fs.close()


# ---------------------------------------------------------------------------
# racing mutations: tickets cancel, nothing stale installs
# ---------------------------------------------------------------------------

def test_racing_rmdir_cancels_inflight_speculative_listing():
    """A rmdir admitted while the batch is wedged mid-fetch: the fetched
    listing must not resurrect overlay state for the removed directory."""
    be = VecGate()
    be.mkdir("pre")
    be.mkdir("pre/d0")                # empty: the racing rmdir succeeds
    be.mkdir("pre/d1")
    fs = CannyFS(be, workers=4, echo_errors=False)
    fs.readdir("pre")                 # miss -> seeds d0, d1 -> batch
    assert be.entered.wait(5.0)       # batch provably mid-fetch
    fs.rmdir("pre/d0")                # racing admitted mutation
    be.gate.set()
    fs.drain()
    ov = fs.engine.overlay
    assert ov.readdir("pre/d0") is None           # not resurrected
    assert ov.lookup("pre/d0") is False
    # the fetch was either cancelled by the ticket or found the dir gone
    # (wasted) — either way nothing installed
    st = fs.stats
    assert st.prefetch_cancelled + st.prefetch_wasted >= 1
    assert "pre/d0" not in be.snapshot()["dirs"]  # really removed
    assert len(fs.ledger) == 0
    fs.close()


def test_racing_rename_cancels_inflight_speculative_listing():
    be = VecGate()
    be.mkdir("pre")
    be.mkdir("pre/d0")
    be.create("pre/d0/f")
    fs = CannyFS(be, workers=4, echo_errors=False)
    fs.readdir("pre")
    assert be.entered.wait(5.0)
    fs.rename("pre", "moved")         # whole-prefix move mid-fetch
    be.gate.set()
    fs.drain()
    ov = fs.engine.overlay
    # no state may survive at the old prefix
    assert ov.readdir("pre") is None
    assert ov.readdir("pre/d0") is None
    st = fs.stats
    assert st.prefetch_cancelled + st.prefetch_wasted >= 1
    snap = be.snapshot()
    assert "moved/d0" in snap["dirs"] and "pre" not in snap["dirs"]
    assert fs.readdir("moved/d0") == ["f"]        # fresh truth, not stale
    assert len(fs.ledger) == 0
    fs.close()


@pytest.mark.parametrize("stealing", [True, False])
def test_racing_invalidation_hammer_8_workers(stealing):
    """Satellite chaos: cold walks racing rmtree/rename under an 8-worker
    pool with stealing on/off.  Invariants: no deadlock, engine ends
    quiescent with executed == submitted, and post-drain answers match
    backend truth (no stale speculative state)."""
    for trial in range(8):
        inner = InMemoryBackend()
        clock = VirtualClock()
        remote = LatencyBackend(
            inner, LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.3,
                                seed=trial), clock=clock)
        dirs = build_cold_tree(inner, n_subdirs=6, files_per_dir=2,
                               grandchildren=3)
        fs = CannyFS(remote, workers=8, echo_errors=False,
                     work_stealing=stealing)
        errors: list[BaseException] = []

        def walker():
            try:
                for _ in fs.walk("cold"):
                    pass
            except OSError:
                pass            # racing removal: legitimate sync surfacing
            except BaseException as e:  # pragma: no cover
                errors.append(e)

        def mutator():
            try:
                if trial % 2 == 0:
                    fs.rmtree("cold/d0")
                else:
                    fs.rename("cold/d1", "cold/moved")
            except OSError:
                pass
            except BaseException as e:  # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=walker),
                   threading.Thread(target=mutator),
                   threading.Thread(target=walker)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        fs.drain()
        assert not errors, errors
        assert fs.engine._inflight == 0
        st = fs.stats
        assert st.executed == st.submitted
        snap = inner.snapshot()
        if trial % 2 == 0:
            assert "cold/d0" not in snap["dirs"]
            assert not fs.exists("cold/d0")
        else:
            assert "cold/d1" not in snap["dirs"]
            assert "cold/moved" in snap["dirs"]
            assert not fs.exists("cold/d1")
        # post-drain: overlay answers agree with the backend for every
        # surviving directory (stale speculative state would diverge)
        for d in sorted(snap["dirs"]):
            if d:
                assert sorted(fs.readdir(d)) == inner.readdir(d), d
        fs.close()


# ---------------------------------------------------------------------------
# LRU-cold insertion: speculation cannot demote the hot window
# ---------------------------------------------------------------------------

def test_cancelled_ticket_refuses_install_unit():
    """Unit: every racing admitted mutation class flips the ticket and
    install_speculative then refuses the listing atomically."""
    cases = [
        ("rmdir", ("pre/d0",)),
        ("remove_tree", ("pre",)),
        ("rename", ("pre", "moved")),
        ("mkdir", ("pre/d0",)),
    ]
    for kind, paths in cases:
        ov = NamespaceOverlay(OverlayPolicy())
        t = ov.speculation_wanted("pre/d0")
        assert t is not None
        ov.on_op(kind, paths)
        assert t.cancelled, kind
        assert ov.install_speculative(t, [("f", None)]) == "cancelled"
    # op-failure invalidation of a child cancels the parent's ticket too
    # (a torn write may have created the child after the fetch)
    ov = NamespaceOverlay(OverlayPolicy())
    t = ov.speculation_wanted("pre/d0")
    ov.invalidate("pre/d0/f")
    assert t.cancelled
    # rollback clears the window: everything cancels
    ov = NamespaceOverlay(OverlayPolicy())
    t = ov.speculation_wanted("pre/d0")
    ov.clear()
    assert t.cancelled
    assert ov.install_speculative(t, [("f", None)]) == "cancelled"


def test_speculative_listings_insert_lru_cold():
    """Unit: at the cached-listings bound, speculative installs evict
    other speculation (or refuse themselves), never the hot listing."""
    ov = NamespaceOverlay(OverlayPolicy(max_cached_listings=2))
    ov.install_listing("hot", [("x", None)])      # hot end of the LRU
    installed = evicted = 0
    for i in range(50):
        t = ov.speculation_wanted(f"spec{i}")
        assert t is not None
        verdict = ov.install_speculative(t, [("y", None)])
        assert verdict in ("installed", "evicted")
        installed += verdict == "installed"
        evicted += verdict == "evicted"
    # the hot listing survived fifty speculative inserts at capacity
    assert ov.readdir("hot") == ["x"]
    assert installed >= 1 and evicted >= 1


def test_prefetch_storm_cannot_demote_hot_or_in_window_listings():
    """Integration (the 10k-dir shape, scaled): prefetching a wide tree
    under a tiny max_cached_listings bound must not evict the hot cached
    listing the consumer is using, nor touch in-window completeness."""
    inner = InMemoryBackend()
    n = 24
    inner.mkdir("w")
    for i in range(n):
        inner.mkdir(f"w/d{i}")
        inner.create(f"w/d{i}/base")
    be = Boundary(inner)
    fs = CannyFS(be, workers=4, echo_errors=False,
                 overlay=OverlayPolicy(max_cached_listings=3))
    fs.mkdir("inwin")                 # in-window completeness: not LRU'd
    fs.readdir("w")                   # miss -> hot cached + seeds the storm
    fs.engine._sched.drain()          # the speculative storm lands
    st = fs.stats
    assert st.prefetch_issued == n
    n_lists = be.counts["readdir_plus"]
    assert n_lists == 1
    # the hot listing survived: still an overlay hit
    assert len(fs.readdir("w")) == n
    assert be.counts["readdir_plus"] == n_lists
    # in-window completeness survived the storm too
    assert fs.engine.overlay.readdir("inwin") == []
    # and the storm bounded itself: at most the LRU bound's worth of
    # speculative listings stuck (the rest evicted each other, cold end)
    stuck = sum(fs.engine.overlay.readdir(f"w/d{i}") is not None
                for i in range(n))
    assert stuck <= 3
    assert st.prefetch_wasted >= n - 3
    fs.drain()
    assert len(fs.ledger) == 0
    fs.close()


# ---------------------------------------------------------------------------
# adaptive batch sizing (bdp_bytes plumbing)
# ---------------------------------------------------------------------------

def test_batch_width_sized_from_live_bdp():
    inner = InMemoryBackend()
    clock = VirtualClock()
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=4.0, data_ms=4.0, jitter_sigma=0.0,
                            bandwidth_mb_s=100.0, seed=2), clock=clock)
    fs = CannyFS(remote, workers=2, echo_errors=False,
                 prefetch=PrefetchPolicy(min_batch=2, max_batch=64))
    pf = fs.engine.prefetcher
    assert pf.batch_width() == 64     # no RTT sample yet: policy cap
    fs.mkdir("warm")                  # one metadata roundtrip calibrates
    fs.drain()
    w = pf.batch_width()
    # 2x BDP (~4ms x 100MB/s = 400kB) / 256B clamps to the policy cap;
    # with a tiny cap the adaptive value must land inside the bounds
    assert 2 <= w <= 64
    fs2 = CannyFS(remote, workers=2, echo_errors=False,
                  prefetch=PrefetchPolicy(adaptive_batch=False, max_batch=7))
    assert fs2.engine.prefetcher.batch_width() == 7
    fs.close()
    fs2.close()


def test_full_inflight_budget_makes_speculation_yield():
    """Speculation never blocks: with the budget nearly exhausted the
    pump drops batches instead of wedging a worker or the caller."""
    inner = InMemoryBackend()
    build_cold_tree(inner, n_subdirs=8)
    fs = CannyFS(inner, workers=2, max_inflight=2, echo_errors=False)
    walked = list(fs.walk("cold"))
    fs.close()
    assert len(walked) == 9           # correct despite dropped speculation
    assert fs.engine._inflight == 0


def test_close_does_not_chase_unbounded_frontier():
    """drain/close quiesce the pipeline: teardown terminates promptly
    even when the frontier still holds unfetched levels."""
    inner = InMemoryBackend()
    for i in range(40):
        inner.mkdir(f"wide{i}" if i < 20 else f"wide0/sub{i}")
    fs = CannyFS(inner, workers=2, echo_errors=False,
                 prefetch=PrefetchPolicy(max_batch=2, max_inflight_batches=1))
    fs.readdir("")                    # seeds 20+ dirs, batches of 2
    t0 = time.monotonic()
    fs.close()
    assert time.monotonic() - t0 < 5.0
    st = fs.stats
    assert st.executed == st.submitted
    assert fs.engine._inflight == 0


# ---------------------------------------------------------------------------
# PR 4 known-gap regression (satellite): anchorless non-structural tails
# ---------------------------------------------------------------------------

def test_rename_waits_for_anchorless_nonstructural_ops_3_deep():
    """A chmod of a *pre-window* file three levels down has no pending
    structural anchor — the old pending_children BFS could not discover
    it, so the rename could win the race and the chmod would ENOENT at
    the old path.  The per-prefix last_op sweep must order the rename
    after it.  Hammered across a real-latency pool where dispatch is
    genuinely concurrent."""
    for trial in range(20):
        inner = InMemoryBackend()
        remote = LatencyBackend(
            inner, LatencyModel(meta_ms=3.0, data_ms=3.0, jitter_sigma=0.0,
                                seed=trial))
        fs = CannyFS(remote, workers=8, echo_errors=False)
        fs.makedirs(f"s{trial}/a")
        fs.write_file(f"s{trial}/a/f", b"deep")
        fs.drain()                    # pre-window: no structural anchors
        fs.chmod(f"s{trial}/a/f", 0o600)      # anchorless, pending
        fs.utimens(f"s{trial}/a/f", 1.0, 2.0)  # ...and a second tail op
        fs.rename(f"s{trial}", f"t{trial}")
        fs.drain()
        assert len(fs.ledger) == 0, \
            (trial, [(e.kind, e.paths, e.error) for e in fs.ledger.entries()])
        snap = inner.snapshot()
        assert snap["files"].get(f"t{trial}/a/f") == b"deep", trial
        fs.close()
