"""Read-ahead-on-vs-off oracle property tests: for any op stream over
pre-populated files — sequential streams, random preads, writes,
truncates, renames, removals, transactional write bursts — running with
the read-side data plane enabled (tiny windows, so several are in
flight per file) and disabled leaves the InMemory backend in the
identical final state with identical read results and ledger outcomes,
including under seeded fault plans.  Mirrors the prefetch/fusion/
overlay equivalence suites.

Where hypothesis is installed the streams are minimised shrinking
examples; where it is absent (the satellite's random-driver fallback)
the same driver runs under seeded ``random`` streams — 120 trials for
the clean property, 50 for the fault-plan property — so the property is
exercised either way instead of silently skipping."""
import random

import pytest

from repro.core import (CannyFS, FaultInjectingBackend, FaultPlan, FaultRule,
                        InMemoryBackend, ReadPolicy, Transaction,
                        TransactionFailedError)

try:
    import hypothesis.strategies as stx
    from hypothesis import HealthCheck, given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# tiny windows force several speculative fetches per streamed file;
# a small batch width forces frequent stat_vec flushes
ON_POLICY = ReadPolicy(adaptive=False, min_bytes=256, max_bytes=1024,
                       max_files=4, stat_batch=3)

# the pre-populated files every run starts from; sizes straddle the
# window (multi-window streams, single-window files, sub-chunk files)
COLD_SIZES = {"pre/s0": 5000, "pre/s1": 300, "pre/s2": 2048, "pre/s3": 9000}
COLD_FILES = sorted(COLD_SIZES)
DIRS = ["pre", "live"]
FILES = COLD_FILES + [f"{d}/f{i}" for d in DIRS for i in range(2)]

OPS = ("stream", "pread", "write", "trunc", "unlink", "rename", "stat",
       "readdir", "rmtree", "remake", "txn")


def _payload(path: str, size: int) -> bytes:
    seed = sum(path.encode())
    return bytes((seed + j) & 0xFF for j in range(size))


def _populate(be):
    be.mkdir("live")
    be.mkdir("pre")
    for f, size in COLD_SIZES.items():
        be.create(f)
        be.write_at(f, 0, _payload(f, size))


def gen_ops(rng: random.Random, n: int = 18):
    """One random op stream (the fallback driver's generator; the
    hypothesis strategy below mirrors it)."""
    out = []
    for _ in range(n):
        op = rng.choice(OPS)
        if op == "stream":
            out.append((op, rng.choice(FILES), rng.choice([300, 700, 1024])))
        elif op == "pread":
            out.append((op, rng.choice(FILES),
                        (rng.randrange(0, 10000), rng.randrange(0, 1500))))
        elif op == "write":
            out.append((op, rng.choice(FILES),
                        bytes(rng.randrange(256)
                              for _ in range(rng.randrange(0, 2000)))))
        elif op == "trunc":
            out.append((op, rng.choice(FILES), rng.randrange(0, 6000)))
        elif op == "rename":
            out.append((op, rng.choice(FILES), rng.choice(FILES)))
        elif op in ("readdir", "remake", "rmtree"):
            out.append((op, rng.choice(DIRS), None))
        elif op == "stat":
            out.append((op, rng.choice(FILES + DIRS), None))
        elif op == "txn":
            out.append((op, rng.choice(DIRS), rng.randrange(2, 6)))
        else:   # unlink
            out.append((op, rng.choice(FILES), None))
    return out


def _drive(fs, ops):
    """Replay ops, collecting every read-class answer.  Destructive ops
    on missing paths are filtered against live-set bookkeeping (the
    valid single-writer task model, as in the sibling suites)."""
    observed = []
    live = set(COLD_FILES)
    live_dirs = {"pre", "live"}
    for i, (op, path, arg) in enumerate(ops):
        if op == "stream" and path in live:
            # the plane's domain: stat for the size, then an exact
            # sequential chunked read — never past EOF
            size = fs.stat(path).size
            chunks, off = [], 0
            while off < size:
                piece = fs.pread(path, off, min(arg, size - off))
                if not piece:
                    break
                chunks.append(piece)
                off += len(piece)
            observed.append(("stream", path, b"".join(chunks)))
        elif op == "pread" and path in live:
            off, size = arg
            observed.append(("pread", path, off, fs.pread(path, off, size)))
        elif op == "write":
            if path.rsplit("/", 1)[0] not in live_dirs:
                continue
            fs.write_file(path, arg)
            live.add(path)
        elif op == "trunc" and path in live:
            fs.truncate(path, arg)
        elif op == "unlink" and path in live:
            fs.unlink(path)
            live.discard(path)
        elif op == "rename":
            dst = arg
            if path not in live or dst == path or dst in live_dirs:
                continue
            if dst.rsplit("/", 1)[0] not in live_dirs:
                continue
            fs.rename(path, dst)
            live.discard(path)
            live.add(dst)
        elif op == "stat":
            st = fs.stat(path)
            observed.append(("stat", path, st.exists, st.is_dir))
        elif op == "readdir" and path in live_dirs:
            observed.append(("readdir", path, fs.readdir(path)))
        elif op == "rmtree" and path in live_dirs:
            fs.rmtree(path)
            live_dirs.discard(path)
            for f in [f for f in live if f.startswith(path + "/")]:
                live.discard(f)
        elif op == "remake" and path not in live_dirs:
            fs.makedirs(path)
            live_dirs.add(path)
        elif op == "txn" and path in live_dirs:
            # transactional write burst: the stat batcher's domain
            # (journaling existence probes fuse into stat_vec batches)
            with Transaction(fs):
                for k in range(arg):
                    fs.write_file(f"{path}/t{i}_{k}", b"txn-%d-%d" % (i, k))
            for k in range(arg):
                live.add(f"{path}/t{i}_{k}")
    return observed


def check_equivalent(ops, workers):
    """The acceptance property: identical final backend state, identical
    stream/pread/stat/readdir answers, identical (empty) ledger."""
    results = []
    for readahead in (ON_POLICY, False):
        be = InMemoryBackend()
        _populate(be)
        fs = CannyFS(be, workers=workers, readahead=readahead,
                     echo_errors=False)
        observed = _drive(fs, ops)
        fs.drain()
        sig = sorted((e.kind, e.paths, getattr(e.error, "errno", None))
                     for e in fs.ledger.entries())
        results.append((be.snapshot(), observed, sig))
        fs.close()
    assert results[0] == results[1]
    assert results[0][2] == []      # clean streams never ledger


def check_fault_equivalent(ops, seed):
    """Under a seeded fault plan the two modes may fail *different*
    backend calls (speculative windows/batches consume read/stat
    matches the unbuffered run never issues, and batch faults are
    advisory), but a clean run (no injected faults in either mode) must
    produce identical state, and no run may ledger more faults than
    were injected."""
    outcome = []
    for readahead in (ON_POLICY, False):
        plan = FaultPlan([FaultRule(error="EIO",
                                    ops=("read", "stat", "write", "unlink",
                                         "remove_tree"),
                                    probability=0.15, max_failures=3)],
                         seed=seed)
        be = InMemoryBackend()
        _populate(be)
        fs = CannyFS(FaultInjectingBackend(be, plan), workers=2,
                     readahead=readahead, echo_errors=False)
        try:
            _drive(fs, ops)
        except (OSError, TransactionFailedError):
            pass   # a sync path may surface an injected fault
        fs.drain()
        n_ledgered = sum(getattr(e.error, "injected", False)
                         for e in fs.ledger.entries())
        outcome.append((plan.injected, n_ledgered, be.snapshot()))
        fs.close()
    for injected, ledgered, _ in outcome:
        # sync-surfaced faults skip the ledger; speculative window and
        # batch faults are advisory and must NEVER be ledgered
        assert ledgered <= injected
    if outcome[0][0] == 0 and outcome[1][0] == 0:
        assert outcome[0][2] == outcome[1][2]


if HAVE_HYPOTHESIS:
    def _op_strategy():
        stream = stx.tuples(stx.just("stream"), stx.sampled_from(FILES),
                            stx.sampled_from([300, 700, 1024]))
        pread = stx.tuples(stx.just("pread"), stx.sampled_from(FILES),
                           stx.tuples(stx.integers(0, 10000),
                                      stx.integers(0, 1500)))
        write = stx.tuples(stx.just("write"), stx.sampled_from(FILES),
                           stx.binary(min_size=0, max_size=2000))
        trunc = stx.tuples(stx.just("trunc"), stx.sampled_from(FILES),
                           stx.integers(0, 6000))
        rename = stx.tuples(stx.just("rename"), stx.sampled_from(FILES),
                            stx.sampled_from(FILES))
        statop = stx.tuples(stx.just("stat"),
                            stx.sampled_from(FILES + DIRS), stx.none())
        readdir = stx.tuples(stx.just("readdir"), stx.sampled_from(DIRS),
                             stx.none())
        unlink = stx.tuples(stx.just("unlink"), stx.sampled_from(FILES),
                            stx.none())
        rmtree = stx.tuples(stx.just("rmtree"), stx.sampled_from(DIRS),
                            stx.none())
        remake = stx.tuples(stx.just("remake"), stx.sampled_from(DIRS),
                            stx.none())
        txn = stx.tuples(stx.just("txn"), stx.sampled_from(DIRS),
                         stx.integers(2, 5))
        return stx.lists(stx.one_of(stream, pread, write, trunc, rename,
                                    statop, readdir, unlink, rmtree, remake,
                                    txn),
                         min_size=1, max_size=20)

    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=_op_strategy(), workers=stx.sampled_from([1, 4]))
    def test_readahead_on_and_off_execution_identical(ops, workers):
        check_equivalent(ops, workers)

    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=_op_strategy(), seed=stx.integers(0, 3))
    def test_readahead_modes_agree_under_fault_plans(ops, seed):
        check_fault_equivalent(ops, seed)
else:
    @pytest.mark.parametrize("trial", range(120))
    def test_readahead_on_and_off_execution_identical_random(trial):
        rng = random.Random(30_000 + trial)
        check_equivalent(gen_ops(rng), workers=rng.choice([1, 4]))

    @pytest.mark.parametrize("trial", range(50))
    def test_readahead_modes_agree_under_fault_plans_random(trial):
        rng = random.Random(40_000 + trial)
        check_fault_equivalent(gen_ops(rng), seed=trial % 4)
