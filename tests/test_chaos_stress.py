"""Concurrency invariants under chaos: threads x eager flags x injected
faults, on the virtual clock so the whole matrix runs in seconds.

Invariants checked:
* per-path FIFO — every file's final content is its writes in submission
  order, even when faults kill some ops on other paths;
* no orphans — after drain() the engine has nothing in flight, every
  submitted op was executed (or cancelled and counted), and every failure
  is accounted for in the ledger;
* the engine survives poisoning races (submitters hitting
  EnginePoisonedError mid-stream) without deadlocking drain().
"""
import threading

import pytest

from repro.core import (CannyFS, EagerFlags, EnginePoisonedError,
                        FaultInjectingBackend, FaultPlan, FaultRule,
                        InMemoryBackend, LatencyBackend, LatencyModel,
                        QuotaBackend, VirtualClock)

N_THREADS = 4
CHUNKS_PER_THREAD = 40


def build_fs(*, flags, fault_rate, seed, workers=8, **fs_kw):
    inner = InMemoryBackend()
    clock = VirtualClock()
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.4,
                            seed=seed), clock=clock)
    rules = []
    if fault_rate:
        # faults only on 'victim' paths so the FIFO files stay clean
        rules.append(FaultRule(error="EIO", ops=("write", "create"),
                               path_glob="*victim*", probability=fault_rate))
    plan = FaultPlan(rules, seed=seed)
    fs = CannyFS(FaultInjectingBackend(remote, plan), flags=flags,
                 max_inflight=256, workers=workers, echo_errors=False,
                 **fs_kw)
    return inner, plan, fs


@pytest.mark.parametrize("eager", [True, False])
@pytest.mark.parametrize("fault_rate", [0.0, 0.3])
def test_per_path_fifo_and_no_orphans(eager, fault_rate):
    flags = EagerFlags() if eager else EagerFlags.all_off()
    inner, plan, fs = build_fs(flags=flags, fault_rate=fault_rate, seed=11)
    fs.makedirs("stress")
    errors: list[BaseException] = []

    def worker(k: int):
        try:
            with fs.open(f"stress/t{k}", "wb") as h:
                for i in range(CHUNKS_PER_THREAD):
                    h.write(bytes([k, i]) * 3)
                    if i % 5 == 0:
                        # interleave chaos-victim traffic on other paths;
                        # sync mode surfaces the fault right here
                        try:
                            fs.write_file(f"stress/victim_{k}_{i}", b"v" * 8)
                        except OSError:
                            assert not eager, "eager faults must be deferred"
        except BaseException as e:  # pragma: no cover - would fail the test
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(k,))
               for k in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fs.drain()
    assert not errors, errors
    # per-path FIFO: each thread's file is its chunks in submission order
    snap = inner.snapshot()
    for k in range(N_THREADS):
        want = b"".join(bytes([k, i]) * 3 for i in range(CHUNKS_PER_THREAD))
        assert snap["files"][f"stress/t{k}"] == want, f"FIFO broken for t{k}"
    # no orphans: everything submitted was executed, nothing left in flight
    st = fs.stats
    assert fs.engine._inflight == 0
    assert st.executed == st.submitted
    assert len(fs.engine._last_op) == 0
    assert len(fs.engine._pending_children) == 0
    # accounting: deferred errors == what the plan injected on eager ops
    if eager:
        assert st.deferred_errors == plan.injected
    assert st.injected_faults == (plan.injected if eager else 0)
    fs.close()


def test_poison_race_does_not_deadlock_drain():
    """abort_on_error poisons while 4 threads are mid-submission; drain()
    must still terminate and later submissions must fail fast."""
    inner, plan, fs = build_fs(flags=EagerFlags(), fault_rate=1.0, seed=5,
                               abort_on_error=True)
    fs.makedirs("stress")
    poisoned_hits = []

    def worker(k: int):
        try:
            for i in range(CHUNKS_PER_THREAD):
                fs.write_file(f"stress/victim_{k}_{i}", b"v")
        except EnginePoisonedError:
            poisoned_hits.append(k)

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fs.drain()          # must not hang on cancelled/poisoned queue
    assert fs.poisoned
    assert fs.engine._inflight == 0
    assert len(fs.ledger) >= 1
    with pytest.raises(EnginePoisonedError):
        fs.create("after")
    fs.engine.reset_poison()
    fs.close()


def test_quota_contention_is_consistent_under_threads():
    """Concurrent writers racing one byte budget: accounting never goes
    negative or over budget, and released bytes are reusable."""
    inner = InMemoryBackend()
    q = QuotaBackend(inner, 10_000)
    fs = CannyFS(q, flags=EagerFlags.all_off(), workers=4, echo_errors=False)
    fs.makedirs("q")
    denied = []

    def worker(k: int):
        for i in range(30):
            try:
                fs.write_file(f"q/t{k}_{i}", b"z" * 512)
            except OSError:
                denied.append((k, i))
                # free one of our own earlier files and move on
                for j in range(i):
                    try:
                        fs.unlink(f"q/t{k}_{j}")
                        break
                    except OSError:
                        pass

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fs.drain()
    live = sum(len(v) for v in inner.snapshot()["files"].values())
    assert 0 <= q.used <= q.budget_bytes
    assert q.used == live, "charged bytes must equal live bytes"
    assert denied, "budget was sized to force contention"
    fs.close()


def _paths_on_few_shards(n_paths: int, n_hot_shards: int = 2,
                         n_shards: int = 16, prefix: str = "hot"):
    """Paths whose scheduler shard (hash(path) % n_shards) lands on only
    ``n_hot_shards`` shards — the uneven load that forces dry workers to
    steal.  Probed at runtime because str hashing is salted per process."""
    out, i = [], 0
    while len(out) < n_paths:
        p = f"stress/{prefix}_{i}"
        if hash(p) % n_shards < n_hot_shards:
            out.append(p)
        i += 1
    return out


@pytest.mark.parametrize("stealing", [True, False])
def test_steal_hammer_uneven_shards_no_lost_or_double_ops(stealing):
    """The work-stealing hammer: 8 pool workers, every op concentrated on
    two of the sixteen ready-queue shards, fault plan active.  Invariants:
    nothing lost (executed == submitted, final content is per-path FIFO),
    nothing double-executed (chunk counts exact), faults all accounted,
    and with stealing ON the dry workers actually stole."""
    inner = InMemoryBackend()
    clock = VirtualClock()
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.4,
                            seed=23), clock=clock)
    rules = [FaultRule(error="EIO", ops=("write", "create"),
                       path_glob="*victim*", probability=0.3)]
    plan = FaultPlan(rules, seed=23)
    fs = CannyFS(FaultInjectingBackend(remote, plan), max_inflight=256,
                 workers=8, echo_errors=False, work_stealing=stealing)
    fs.makedirs("stress")
    per_thread = (CHUNKS_PER_THREAD + 4) // 5
    hot = _paths_on_few_shards(N_THREADS)
    victims = _paths_on_few_shards(N_THREADS * per_thread, prefix="victim")
    errors: list[BaseException] = []

    def worker(k: int):
        try:
            with fs.open(hot[k], "wb") as h:
                for i in range(CHUNKS_PER_THREAD):
                    h.write(bytes([k, i]) * 3)
                    if i % 5 == 0:
                        fs.write_file(victims[k * per_thread + i // 5],
                                      b"v" * 8)
        except BaseException as e:  # pragma: no cover - would fail the test
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(k,))
               for k in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fs.drain()
    assert not errors, errors
    snap = inner.snapshot()
    for k in range(N_THREADS):
        want = b"".join(bytes([k, i]) * 3 for i in range(CHUNKS_PER_THREAD))
        assert snap["files"][hot[k]] == want, f"FIFO broken for {hot[k]}"
    st = fs.stats
    assert fs.engine._inflight == 0
    assert st.executed == st.submitted          # nothing lost or doubled
    assert len(fs.engine._last_op) == 0
    assert len(fs.engine._pending_children) == 0
    assert st.deferred_errors == plan.injected  # every fault accounted
    if stealing:
        assert st.steals > 0, "uneven shards with 8 workers must steal"
    else:
        assert st.steals == 0
    fs.close()


def test_steal_hammer_poison_propagates_cleanly():
    """abort_on_error under concentrated-shard load: poisoning mid-steal
    must cancel the queued ops across every shard deque, drain() must
    terminate with parked workers woken, and submissions fail fast."""
    inner = InMemoryBackend()
    clock = VirtualClock()
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.4,
                            seed=7), clock=clock)
    plan = FaultPlan([FaultRule(error="EIO", ops=("write", "create"),
                                path_glob="*victim*", probability=1.0)],
                     seed=7)
    fs = CannyFS(FaultInjectingBackend(remote, plan), max_inflight=256,
                 workers=8, echo_errors=False, abort_on_error=True)
    fs.makedirs("stress")
    victims = _paths_on_few_shards(4 * CHUNKS_PER_THREAD, prefix="victim")
    poisoned_hits = []

    def worker(k: int):
        try:
            for i in range(CHUNKS_PER_THREAD):
                fs.write_file(victims[k * CHUNKS_PER_THREAD + i], b"v")
        except EnginePoisonedError:
            poisoned_hits.append(k)

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fs.drain()          # must not hang on cancelled ops in any shard deque
    assert fs.poisoned
    assert fs.engine._inflight == 0
    assert len(fs.ledger) >= 1
    with pytest.raises(EnginePoisonedError):
        fs.create("after")
    fs.engine.reset_poison()
    fs.close()


def test_matrix_runs_fast_enough_for_ci():
    """The whole chaos matrix above relies on the virtual clock; this guard
    asserts simulated time actually decoupled from real time."""
    import time
    t0 = time.monotonic()
    inner, plan, fs = build_fs(flags=EagerFlags(), fault_rate=0.2, seed=9)
    fs.makedirs("stress")
    for i in range(200):
        fs.write_file(f"stress/victim_{i}", b"x" * 2048)
    fs.drain()
    clock = fs.backend.inner.clock     # FaultInjecting -> Latency
    assert clock.now() > 0.2           # simulated I/O seconds accumulated
    assert time.monotonic() - t0 < 5.0  # ...in well under real-time
    fs.close()
