"""Op-fusion tests: the optimizer layer's peephole rules, the
fused-vs-unfused oracle property, and fusion x faults interaction.

Determinism technique: a ``GateBackend`` wedges the engine's single worker
on a sentinel op, so every subsequently submitted op is *pending* (and
therefore rewritable) until the gate opens — peephole decisions become
exact, not race-dependent."""
import errno
import threading
import time

import pytest

from repro.core import (CannyFS, EagerFlags, EnginePoisonedError,
                        FaultInjectingBackend, FaultPlan, FaultRule,
                        FusionPolicy, InMemoryBackend, LatencyBackend,
                        LatencyModel, QuotaBackend, ShortWriteError,
                        Transaction, TransactionFailedError, VirtualClock,
                        run_transaction)

GATE = "gate_sentinel"


class GateBackend(InMemoryBackend):
    """Records data/metadata calls; fsync(GATE) blocks until released.
    write_vec is inherited from the base loop, so ``write_at`` records one
    entry per executed segment and ``vec_calls`` one per fused batch."""

    def __init__(self):
        super().__init__()
        self.gate = threading.Event()
        self.entered = threading.Event()   # the worker reached the gate
        self.calls: list[tuple] = []
        self.vec_calls: list[tuple] = []

    def fsync(self, path):
        if path == GATE:
            self.entered.set()
            self.gate.wait()

    def write_at(self, p, o, data):
        self.calls.append(("write_at", p, o, bytes(data)))
        return super().write_at(p, o, data)

    def write_vec(self, p, segments):
        self.vec_calls.append((p, [(o, len(d)) for o, d in segments]))
        return super().write_vec(p, segments)

    def create(self, p):
        self.calls.append(("create", p))
        super().create(p)

    def unlink(self, p):
        self.calls.append(("unlink", p))
        super().unlink(p)

    def chmod(self, p, m):
        self.calls.append(("chmod", p, m))
        super().chmod(p, m)

    def utimens(self, p, a, m):
        self.calls.append(("utimens", p, a, m))
        super().utimens(p, a, m)

    def truncate(self, p, s):
        self.calls.append(("truncate", p, s))
        super().truncate(p, s)

    def kinds(self, kind):
        return [c for c in self.calls if c[0] == kind]


def gated_fs(**kw):
    be = GateBackend()
    fs = CannyFS(be, workers=1, echo_errors=False, **kw)
    fs.create(GATE)
    fs.drain()
    fs.fsync(GATE)        # wedges the single worker until be.gate.set()
    be.entered.wait()     # worker provably wedged: later submissions pend
    return be, fs


def release(be, fs):
    be.gate.set()
    fs.drain()


# ---------------------------------------------------------------------------
# rule 1: write coalescing -> one vectored backend call
# ---------------------------------------------------------------------------

def test_streamed_writes_coalesce_into_one_write_vec():
    be, fs = gated_fs()
    with fs.open("f", "wb") as h:
        for i in range(10):
            h.write(bytes([i]) * 4)
    release(be, fs)
    assert fs.read_file("f") == b"".join(bytes([i]) * 4 for i in range(10))
    assert len(be.vec_calls) == 1
    # contiguous chunks merged into a single segment
    assert be.vec_calls[0][1] == [(0, 40)]
    assert fs.stats.fused_writes == 9
    assert fs.stats.executed == fs.stats.submitted
    fs.close()


def test_non_contiguous_and_overlapping_segments_apply_in_order():
    be, fs = gated_fs()
    fs._write_at("f", 0, b"aaaaaaaa")
    fs._write_at("f", 16, b"bbbb")      # gap -> second segment
    fs._write_at("f", 2, b"XX")         # overlap -> applied last
    release(be, fs)
    got = fs.read_file("f")
    assert got == b"aaXXaaaa" + b"\0" * 8 + b"bbbb"
    assert len(be.vec_calls) == 1 and len(be.vec_calls[0][1]) == 3
    fs.close()


def test_fusion_policy_bounds_rotate_ops():
    be, fs = gated_fs(fusion=FusionPolicy(max_segments=128, max_bytes=64))
    with fs.open("f", "wb") as h:
        for i in range(10):
            h.write(b"x" * 16)          # 64-byte cap -> new op every 4
    release(be, fs)
    assert fs.read_file("f") == b"x" * 160
    assert len(be.vec_calls) == 3       # 64+64+32
    fs.close()


def test_fusion_off_one_backend_call_per_write():
    be, fs = gated_fs(fusion=False)
    with fs.open("f", "wb") as h:
        for i in range(5):
            h.write(bytes([i]))
    release(be, fs)
    assert fs.read_file("f") == bytes(range(5))
    assert len(be.vec_calls) == 5
    assert fs.stats.fused_writes == 0
    fs.close()


def test_writes_do_not_fuse_across_regions():
    """A fused failure must land in exactly one region's ledger scope, so
    ops from different transaction regions never share a backend call."""
    be, fs = gated_fs()
    fs._write_at("f", 0, b"pre")        # region None
    with Transaction(fs) as txn:
        fs._write_at("f", 3, b"txn")    # contiguous, but region differs
        release(be, fs)
    assert txn.committed
    assert len(be.vec_calls) == 2
    assert fs.read_file("f") == b"pretxn"
    fs.close()


# ---------------------------------------------------------------------------
# rule 2: metadata folding (last-wins)
# ---------------------------------------------------------------------------

def test_adjacent_chmod_folds_to_last_value():
    be, fs = gated_fs()
    fs.write_file("f", b"d")
    fs.chmod("f", 0o600)
    fs.chmod("f", 0o640)
    fs.chmod("f", 0o644)
    release(be, fs)
    assert be.kinds("chmod") == [("chmod", "f", 0o644)]
    assert fs.stats.folded_meta == 2
    assert fs.stat("f").mode == 0o644
    fs.close()


def test_utimens_and_truncate_fold():
    be, fs = gated_fs()
    fs.write_file("f", b"dddddddd")
    fs.utimens("f", 1.0, 1.0)
    fs.utimens("f", 2.0, 2.0)
    fs.truncate("f", 6)
    fs.truncate("f", 2)
    release(be, fs)
    assert be.kinds("utimens") == [("utimens", "f", 2.0, 2.0)]
    assert be.kinds("truncate") == [("truncate", "f", 2)]
    assert fs.read_file("f") == b"dd"
    assert fs.stats.folded_meta == 2
    fs.close()


def test_truncate_grow_after_shrink_does_not_fold():
    """t(4);t(9) zero-pads the cut region — folding to t(9) alone would
    leave the original bytes.  Only shrink-further folds are last-wins."""
    be, fs = gated_fs()
    fs.write_file("f", b"x" * 12)
    fs.truncate("f", 4)
    fs.truncate("f", 9)     # grow: must stay a separate backend op
    release(be, fs)
    assert be.kinds("truncate") == [("truncate", "f", 4),
                                    ("truncate", "f", 9)]
    assert fs.read_file("f") == b"x" * 4 + b"\0" * 5
    fs.close()


def test_different_kinds_do_not_fold():
    be, fs = gated_fs()
    fs.write_file("f", b"d")
    fs.chmod("f", 0o600)
    fs.utimens("f", 1.0, 1.0)
    fs.chmod("f", 0o644)    # tip is utimens -> no fold (order matters)
    release(be, fs)
    assert len(be.kinds("chmod")) == 2
    assert fs.stats.folded_meta == 0
    fs.close()


# ---------------------------------------------------------------------------
# rule 3: unlink elision
# ---------------------------------------------------------------------------

def test_create_write_chain_unlinked_in_window_never_hits_backend():
    be, fs = gated_fs()
    fs.write_file("tmp", b"x" * 100)    # create + write
    fs.chmod("tmp", 0o600)
    fs.unlink("tmp")
    release(be, fs)
    assert be.kinds("create") == [("create", GATE)]  # only the sentinel
    assert be.vec_calls == []
    assert be.kinds("chmod") == []
    # the tolerant unlink ran (and swallowed the file's absence)
    assert be.kinds("unlink") == [("unlink", "tmp")]
    assert fs.stats.elided_ops == 3
    assert fs.stats.bytes_elided == 100
    assert len(fs.ledger) == 0
    assert not fs.exists("tmp")
    assert fs.stats.executed == fs.stats.submitted
    fs.close()


def test_unlink_of_preexisting_file_still_removes_it():
    """Elision drops the pending O_TRUNC create+write, but the unlink must
    still remove the file that existed before the window."""
    be, fs = gated_fs()
    release(be, fs)                     # let setup run for real
    fs.write_file("keep", b"old")
    fs.drain()
    be.calls.clear()
    be.vec_calls.clear()
    be.gate.clear()
    be.entered.clear()
    fs.fsync(GATE)                      # wedge again
    be.entered.wait()
    fs.write_file("keep", b"new")       # pending rewrite chain
    fs.unlink("keep")
    release(be, fs)
    assert be.vec_calls == []           # rewrite elided
    assert not fs.exists("keep")
    assert "keep" not in be.snapshot()["files"]
    assert len(fs.ledger) == 0
    fs.close()


def test_elided_create_in_transaction_commits_and_rolls_back_clean():
    """An elided op's region must still commit/roll back correctly: the
    elided create journals nothing, so rollback has nothing to remove and
    the backend is untouched either way."""
    be, fs = gated_fs()
    with Transaction(fs) as txn:
        fs.write_file("t/f", b"z" * 32)   # under pending mkdir
        fs.mkdir("t") if False else None
        fs.unlink("t/f")
        release(be, fs)
    assert txn.committed
    assert txn._created == {}            # nothing journaled
    assert "t/f" not in be.snapshot()["files"]
    fs.close()


def test_elision_stops_at_sealed_op():
    """A barrier is an observation point: ops it waits on are sealed and
    must execute even if the path is later unlinked."""
    be, fs = gated_fs()
    fs.write_file("f", b"observed")
    waiter = threading.Thread(target=fs.engine.barrier, args=("f",))
    waiter.start()
    for _ in range(200):
        if fs.stats.barrier_waits:
            break
        time.sleep(0.005)
    assert fs.stats.barrier_waits == 1
    fs.unlink("f")                       # chain is sealed: no elision
    release(be, fs)
    waiter.join()
    assert fs.stats.elided_ops == 0
    assert len(be.vec_calls) == 1        # the observed write really ran
    assert be.kinds("unlink") == [("unlink", "f")]
    assert len(fs.ledger) == 0
    fs.close()


def test_barrier_seal_prevents_fusing_more_into_waited_op():
    be, fs = gated_fs()
    fs._write_at("f", 0, b"aaaa")
    waiter = threading.Thread(target=fs.engine.barrier, args=("f",))
    waiter.start()
    for _ in range(200):
        if fs.stats.barrier_waits:
            break
        time.sleep(0.005)
    fs._write_at("f", 4, b"bbbb")        # sealed tip -> separate op
    release(be, fs)
    waiter.join()
    assert fs.stats.fused_writes == 0
    assert len(be.vec_calls) == 2
    assert fs.read_file("f") == b"aaaabbbb"
    fs.close()


def test_poisoned_engine_fails_fast_even_with_fusable_tip():
    """Fusion must not ACK writes into a poisoned engine: a dep-blocked
    (hence uncancelled) pending tip is absorbable, but the submit path's
    fail-fast guarantee has to win."""
    be, fs = gated_fs(abort_on_error=True)
    with fs.open("f", "wb") as h:
        h.write(b"a")               # create (ready) + write (dep-blocked)
    fs.engine._sched.poison()
    with pytest.raises(EnginePoisonedError):
        fs._write_at("f", 1, b"b")  # would fuse; must fail fast instead
    with pytest.raises(EnginePoisonedError):
        fs.chmod("f", 0o600)
    with pytest.raises(EnginePoisonedError):
        fs.unlink("f")
    fs.engine.reset_poison()
    release(be, fs)
    fs.close()


def test_sync_unlink_mode_stays_strict():
    fs = CannyFS(InMemoryBackend(), flags=EagerFlags.all_off(), workers=2,
                 echo_errors=False)
    with pytest.raises(FileNotFoundError):
        fs.unlink("missing")
    fs.close()


def test_unlink_without_pending_chain_still_reports_enoent():
    be, fs = gated_fs()
    fs.unlink("never_existed")           # no chain -> strict unlink
    release(be, fs)
    sig = [(e.kind, e.paths) for e in fs.ledger.entries()]
    assert sig == [("unlink", ("never_existed",))]
    fs.close()


# ---------------------------------------------------------------------------
# fusion x faults: semantics are per fused backend call
# ---------------------------------------------------------------------------

def test_fault_rule_fires_per_fused_op_not_per_original_write():
    plan = FaultPlan([FaultRule(error="EIO", ops=("write",))])
    be = GateBackend()
    fs = CannyFS(FaultInjectingBackend(be, plan), workers=1,
                 echo_errors=False)
    fs.create(GATE)
    fs.drain()
    fs.fsync(GATE)
    for i in range(6):
        fs._write_at("f", i, bytes([i]))   # one fused op
    release(be, fs)
    # six submitted writes, ONE matching backend call, ONE ledger entry
    assert plan.stats()["ops_seen"].get("write", 0) == 1
    assert plan.injected == 1
    sig = [(e.kind, e.paths, e.error.errno) for e in fs.ledger.entries()]
    assert sig == [("write", ("f",), errno.EIO)]
    assert fs.stats.injected_faults == 1
    fs.close()


def test_short_write_fault_tears_fused_vector_and_ledgers():
    plan = FaultPlan([FaultRule(outcome="short", short_fraction=0.5,
                                ops=("write",), max_failures=1)])
    be = GateBackend()
    fs = CannyFS(FaultInjectingBackend(be, plan), workers=1,
                 echo_errors=False)
    fs.create(GATE)
    fs.drain()
    fs.fsync(GATE)
    with fs.open("torn", "wb") as h:
        h.write(b"a" * 32)
        h.write(b"b" * 32)
    release(be, fs)
    # half the fused 64 bytes landed; the tear surfaced as a deferred error
    assert be.snapshot()["files"]["torn"] == b"a" * 32
    entries = fs.ledger.entries()
    assert len(entries) == 1 and isinstance(entries[0].error, ShortWriteError)
    assert entries[0].error.errno == errno.EIO
    assert entries[0].error.written == 32
    assert entries[0].error.expected == 64
    fs.close()


def test_short_write_fails_transaction_then_retry_converges():
    plan = FaultPlan([FaultRule(outcome="short", short_fraction=0.25,
                                ops=("write",), max_failures=1)])
    inner = InMemoryBackend()
    fs = CannyFS(FaultInjectingBackend(inner, plan), echo_errors=False)

    def body(fs):
        fs.makedirs("out")
        with fs.open("out/data", "wb") as h:
            h.write(b"q" * 64)

    run_transaction(fs, body, retries=3)
    fs.drain()
    # attempt 1 tore, was rolled back (torn file journaled+removed);
    # attempt 2 wrote the whole payload
    assert inner.snapshot()["files"]["out/data"] == b"q" * 64
    assert fs.stats.retries == 1 and fs.stats.rollbacks == 1
    assert len(fs.ledger) == 0
    fs.close()


def test_short_write_in_sync_mode_raises_directly():
    plan = FaultPlan([FaultRule(outcome="short", short_fraction=0.0,
                                ops=("write",), max_failures=1)])
    fs = CannyFS(FaultInjectingBackend(InMemoryBackend(), plan),
                 flags=EagerFlags.all_off(), workers=2, echo_errors=False)
    fs.makedirs("d")
    with pytest.raises(ShortWriteError):
        fs._write_at("d/f", 0, b"xyz")
    fs.close()


def test_latency_spike_slows_op_without_failing_it():
    clock = VirtualClock()
    plan = FaultPlan([FaultRule(outcome="delay", delay_s=0.5,
                                ops=("write",), max_failures=2)])
    fs = CannyFS(FaultInjectingBackend(InMemoryBackend(), plan, clock=clock),
                 echo_errors=False)
    fs.write_file("slow", b"v")
    fs.drain()
    assert clock.now() >= 0.5            # the spike was paid (virtually)
    assert plan.delayed == 1
    assert plan.injected == 0            # a spike is not a fault
    assert len(fs.ledger) == 0
    assert fs.read_file("slow") == b"v"
    fs.close()


def test_short_rule_does_not_match_non_write_ops():
    plan = FaultPlan([FaultRule(outcome="short")])   # ops=None: all kinds
    assert plan.check("mkdir", "d") is None
    assert plan.check("unlink", "f") is None
    tok = plan.check("write", "f")
    assert tok is not None and tok.outcome == "short"


# ---------------------------------------------------------------------------
# write_vec composition through the decorator stack
# ---------------------------------------------------------------------------

def test_write_vec_through_quota_charges_per_fused_op():
    q = QuotaBackend(InMemoryBackend(), 100)
    q.mkdir("d")
    assert q.write_vec("d/f", [(0, b"x" * 40), (40, b"y" * 40)]) == 80
    assert q.used == 80
    with pytest.raises(OSError) as ei:
        q.write_vec("d/g", [(0, b"z" * 30)])
    assert ei.value.errno == errno.EDQUOT
    assert q.used == 80                  # failed vector charged nothing
    q.unlink("d/f")
    assert q.used == 0


def test_write_vec_quota_uncharges_torn_tail():
    plan = FaultPlan([FaultRule(outcome="short", short_fraction=0.5,
                                ops=("write",), max_failures=1)])
    inner = InMemoryBackend()
    stack = QuotaBackend(FaultInjectingBackend(inner, plan), 1000)
    stack.mkdir("d")
    n = stack.write_vec("d/f", [(0, b"x" * 64)])
    assert n == 32
    # only the landed prefix stays charged
    assert stack.used == 32
    assert inner.snapshot()["files"]["d/f"] == b"x" * 32


def test_write_vec_through_latency_is_one_roundtrip():
    inner = InMemoryBackend()
    clock = VirtualClock()
    lat = LatencyBackend(inner, LatencyModel(meta_ms=2.0, data_ms=2.0,
                                             jitter_sigma=0.0), clock=clock)
    lat.write_vec("f", [(0, b"a" * 10), (10, b"b" * 10)])
    assert lat.op_count == 1
    assert inner.snapshot()["files"]["f"] == b"a" * 10 + b"b" * 10


def test_base_write_vec_loop_respects_overridden_write_at():
    """Test doubles that override write_at must still see every segment —
    InMemoryBackend deliberately inherits the loop fallback."""
    seen = []

    class Spy(InMemoryBackend):
        def write_at(self, p, o, d):
            seen.append((p, o, len(d)))
            return super().write_at(p, o, d)

    s = Spy()
    assert s.write_vec("f", [(0, b"ab"), (2, b"cd")]) == 4
    assert seen == [("f", 0, 2), ("f", 2, 2)]


# ---------------------------------------------------------------------------
# end-to-end: the acceptance workload, deterministically
# ---------------------------------------------------------------------------

def _window_workload(fusion):
    """Chunked extract + manifest removal entirely inside one unobserved
    window (worker gated), mirroring benchmarks.fusion_table."""
    be = GateBackend()
    fs = CannyFS(be, workers=1, fusion=fusion, echo_errors=False)
    fs.create(GATE)
    fs.drain()
    base_calls = len(be.calls)
    fs.fsync(GATE)
    files = [(f"t/f{i}", bytes([i]) * 64) for i in range(8)]
    fs.makedirs("t")
    for path, data in files:
        with fs.open(path, "wb") as h:
            for lo in range(0, len(data), 16):
                h.write(data[lo:lo + 16])
        fs.chmod(path, 0o644)
    for path, _ in files:
        fs.unlink(path)
    fs.rmdir("t")
    release(be, fs)
    snap = be.snapshot()
    stats = fs.stats
    data_calls = len(be.calls) - base_calls + len(be.vec_calls)
    fs.close()
    return snap, stats, data_calls


def test_fusion_beats_nofusion_on_extract_rm_window():
    snap_f, st_f, ops_f = _window_workload(True)
    snap_n, st_n, ops_n = _window_workload(False)
    # identical final state: tree fully gone either way
    for snap in (snap_f, snap_n):
        assert all(not p.startswith("t") for p in snap["files"])
        assert "t" not in snap["dirs"]
    assert snap_f == snap_n
    # the acceptance criterion: fewer backend ops, with fusion evidence
    assert ops_f < ops_n
    assert st_f.fused_writes > 0
    assert st_f.elided_ops > 0
    assert st_f.bytes_elided > 0
    assert st_n.fused_writes == 0 and st_n.elided_ops == 0


def test_engine_quiescent_after_heavy_fusion():
    be, fs = gated_fs()
    for i in range(20):
        with fs.open(f"d{i}", "wb") as h:
            for j in range(5):
                h.write(bytes([j]))
        fs.chmod(f"d{i}", 0o600)
        fs.chmod(f"d{i}", 0o644)
    for i in range(0, 20, 2):
        fs.unlink(f"d{i}")
    release(be, fs)
    st = fs.stats
    assert st.executed == st.submitted
    assert fs.engine._inflight == 0
    assert len(fs.engine._last_op) == 0
    assert len(fs.engine._pending_children) == 0
    assert len(be.snapshot()["files"]) == 10 + 1   # evens gone + sentinel
    fs.close()


def test_thread_per_op_executor_with_fusion():
    be = InMemoryBackend()
    fs = CannyFS(be, executor="thread_per_op", workers=1, echo_errors=False)
    with fs.open("f", "wb") as h:
        for i in range(30):
            h.write(bytes([i]))
    fs.unlink("f")
    fs.write_file("g", b"done")
    fs.close()
    snap = be.snapshot()
    assert "f" not in snap["files"] and snap["files"]["g"] == b"done"
