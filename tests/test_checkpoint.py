"""Transactional checkpoint tests: roundtrip, commit semantics, rollback,
failure injection, reshard-on-restore, and hypothesis pytree roundtrips."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (see requirements-dev.txt)")
import hypothesis.strategies as stx
from hypothesis import HealthCheck, given, settings

from repro.checkpoint import (COMMIT_FILE, TransactionalCheckpointManager)
from repro.core import CannyFS, InMemoryBackend, LatencyBackend, LatencyModel


def make_fs(backend=None):
    return CannyFS(backend or InMemoryBackend(), max_inflight=1000,
                   workers=8)


def test_roundtrip_dtypes_and_structure():
    fs = make_fs()
    mgr = TransactionalCheckpointManager(fs, "ck")
    state = {
        "params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                   "layers": [{"a": np.ones((2, 2), np.float32)},
                              {"a": np.zeros((2, 2), np.float32)}]},
        "bf16": jnp.ones((5,), jnp.bfloat16) * 1.5,
        "step": np.asarray(3, np.int32),
    }
    mgr.save(3, state, block=True)
    step, out = mgr.restore(state)
    assert step == 3
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(state)):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    fs.close()


def test_commit_marker_written_last():
    """COMMIT must not exist until every shard is durable: inject latency
    and poll the backing store while the save drains."""
    inner = InMemoryBackend()
    lat = LatencyBackend(inner, LatencyModel(meta_ms=2.0, data_ms=2.0,
                                             jitter_sigma=0.0))
    fs = CannyFS(lat, max_inflight=1000, workers=8)
    mgr = TransactionalCheckpointManager(fs, "ck")
    state = {"w": np.ones(512, np.float32)}
    res = mgr.save(1, state)
    seen_commit_early = False
    while mgr._finalizer is not None and mgr._finalizer.is_alive():
        snap = inner.snapshot()
        if any(COMMIT_FILE in p for p in snap["files"]):
            shard = [p for p in snap["files"] if p.endswith("w.bin")]
            if not shard:
                seen_commit_early = True
    mgr.wait_for_save()
    assert not seen_commit_early
    assert mgr.results[-1].ok
    fs.close()


def test_failed_save_rolls_back_and_next_succeeds():
    class Flaky(InMemoryBackend):
        fail = True

        def write_at(self, p, o, d):
            if self.fail and p.endswith("w.bin"):
                raise OSError(5, "io")
            return super().write_at(p, o, d)

    be = Flaky()
    fs = CannyFS(be)
    mgr = TransactionalCheckpointManager(fs, "ck")
    state = {"w": np.ones(16, np.float32)}
    mgr.save(1, state, block=True)
    assert not mgr.results[-1].ok
    assert mgr.list_steps() == []
    # the partial dir was rolled back
    assert all("step_" not in p for p in be.snapshot()["files"])
    be.fail = False
    fs.ledger.clear()
    mgr.save(2, state, block=True)
    assert mgr.results[-1].ok and mgr.list_steps() == [2]
    step, out = mgr.restore(state)
    assert step == 2
    fs.close()


def test_gc_keeps_latest():
    fs = make_fs()
    mgr = TransactionalCheckpointManager(fs, "ck", keep=2)
    state = {"w": np.ones(4, np.float32)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state, block=True)
    assert mgr.list_steps() == [3, 4]
    fs.close()


def test_restore_with_resharding():
    """Saved artifact is mesh-agnostic: restore onto explicit shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_debug_mesh
    fs = make_fs()
    mgr = TransactionalCheckpointManager(fs, "ck")
    state = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
    mgr.save(1, state, block=True)
    mesh = make_debug_mesh(1)
    sh = {"w": NamedSharding(mesh, P(None, None))}
    step, out = mgr.restore(state, shardings=sh)
    assert isinstance(out["w"], jax.Array)
    np.testing.assert_array_equal(np.asarray(out["w"]), state["w"])
    fs.close()


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(data=stx.dictionaries(
    keys=stx.text(alphabet="abcdef", min_size=1, max_size=6),
    values=stx.one_of(
        stx.integers(0, 255).map(lambda n: np.arange(n, dtype=np.float32)),
        stx.integers(1, 16).map(
            lambda n: np.ones((n, 3), np.int32)),
    ),
    min_size=1, max_size=6))
def test_checkpoint_roundtrip_property(data):
    fs = make_fs()
    mgr = TransactionalCheckpointManager(fs, "ck")
    mgr.save(1, data, block=True)
    assert mgr.results[-1].ok
    _, out = mgr.restore(data)
    for k in data:
        np.testing.assert_array_equal(out[k], data[k])
        assert out[k].dtype == data[k].dtype
    fs.close()
