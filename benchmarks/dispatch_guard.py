"""CI regression guard for PR 4's dispatch hot path + same-breath bulk
removal.  Emits ``BENCH_pr4.json`` and FAILS (exit 1) when either
tentpole regressed:

1. **Dispatch scaling** — the extraction op stream runs on the virtual
   clock at 1 worker and at 8 workers.  Each backend call 'sleeps' its
   modelled latency on the executing worker's *per-thread* virtual
   timeline, so ``VirtualClock.makespan()`` (the busiest worker's
   accumulated wait) is the schedule's critical path and
   ``ops / makespan`` the dispatch throughput — deterministic, no real
   sleeps.  With per-shard ready queues + work stealing the 8-worker pool
   spreads the load and must clear >= 2x the single-worker throughput;
   a dispatch bottleneck (or a stealing bug starving shards) collapses
   the ratio toward 1x.  Fusion is off for this phase so both runs
   execute the identical op count.

2. **Same-breath extract_then_rm** — extraction and readdir-driven
   removal in one breath (mkdirs still pending at fuse time): the
   exec-time re-verification pass must recover the paper's headline
   collapse.  Real (small) latency so the queue genuinely backs up, as
   in the fusion table.  Fails if ``bulk_removes == 0`` or the removal
   degenerated to >= one backend op per entry.

Scale with REPRO_BENCH_SCALE as usual (CI runs 0.1).

    PYTHONPATH=src REPRO_BENCH_SCALE=0.1 python -m benchmarks.dispatch_guard
"""
from __future__ import annotations

import json
import sys

from repro.core import CannyFS, InMemoryBackend, LatencyBackend, LatencyModel

from .workloads import (PacedVirtualClock, TreeSpec, extract_then_rm,
                        extract_tree, synth_tree)

MIN_SPEEDUP = 2.0


def dispatch_throughput(dirs, files, workers: int) -> dict:
    clock = PacedVirtualClock()
    remote = LatencyBackend(
        InMemoryBackend(),
        LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.0, seed=4),
        clock=clock)
    fs = CannyFS(remote, max_inflight=4000, workers=workers,
                 fusion=False)   # fixed op count: pure dispatch measure
    extract_tree(fs, dirs, files)
    fs.close()
    st = fs.stats
    makespan = clock.makespan()
    return {
        "workers": workers,
        "ops": st.executed,
        "makespan_virtual_s": makespan,
        # per-worker virtual busy seconds: how evenly stealing spread the
        # load (the makespan is this list's max)
        "worker_virtual_s": sorted(clock.thread_seconds().values(),
                                   reverse=True),
        "ops_per_virtual_s": st.executed / makespan if makespan else 0.0,
        "steals": st.steals,
        "parks": st.parks,
        "ledger": len(fs.ledger),
    }


def same_breath_extract_rm(dirs, files) -> dict:
    inner = InMemoryBackend()
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=3.0, data_ms=3.0, jitter_sigma=0.0,
                            server_slots=8, seed=9))
    fs = CannyFS(remote, max_inflight=4000, workers=8)
    extract_then_rm(fs, dirs, files)
    fs.close()
    st = fs.stats
    snap = inner.snapshot()
    present = set(snap["files"]) | set(snap["dirs"])
    leftover = [p for p in (*dirs, *(p for p, _ in files)) if p in present]
    return {
        "entries": len(dirs) + len(files),    # the workload manifest
        "backend_ops": remote.op_count,
        "bulk_removes": st.bulk_removes,
        "bulk_reverify_promoted": st.bulk_reverify_promoted,
        "bulk_reverify_demoted": st.bulk_reverify_demoted,
        "elided_ops": st.elided_ops,
        "adaptive_max_bytes": st.adaptive_max_bytes,
        "leftover": len(leftover),
        "ledger": len(fs.ledger),
    }


def main() -> int:
    spec = TreeSpec(n_files=240, n_dirs=20).scaled()
    dirs, files = synth_tree(spec)
    one = dispatch_throughput(dirs, files, workers=1)
    eight = dispatch_throughput(dirs, files, workers=8)
    ratio = (eight["ops_per_virtual_s"] / one["ops_per_virtual_s"]
             if one["ops_per_virtual_s"] else 0.0)
    breath = same_breath_extract_rm(dirs, files)
    report = {
        "dispatch": {"w1": one, "w8": eight, "speedup": ratio,
                     "min_speedup": MIN_SPEEDUP},
        "extract_then_rm": breath,
    }
    with open("BENCH_pr4.json", "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"dispatch: {one['ops']} ops  w1={one['ops_per_virtual_s']:.0f}/s "
          f"w8={eight['ops_per_virtual_s']:.0f}/s  speedup={ratio:.2f}x "
          f"(steals={eight['steals']} parks={eight['parks']})")
    print(f"extract_then_rm: entries={breath['entries']} "
          f"backend_ops={breath['backend_ops']} "
          f"bulk_removes={breath['bulk_removes']} "
          f"reverify_promoted={breath['bulk_reverify_promoted']} "
          f"demoted={breath['bulk_reverify_demoted']}")
    ok = True
    if ratio < MIN_SPEEDUP:
        print(f"FAIL: 8-worker dispatch throughput is {ratio:.2f}x the "
              f"single worker (need >= {MIN_SPEEDUP}x) — the sharded "
              "ready queues / work stealing regressed", file=sys.stderr)
        ok = False
    if one["ledger"] or eight["ledger"] or breath["ledger"]:
        print("FAIL: deferred errors during a clean run", file=sys.stderr)
        ok = False
    if breath["bulk_removes"] == 0:
        print("FAIL: bulk_removes == 0 — the same-breath extract_then_rm "
              "workload no longer fuses its removal (exec-time "
              "re-verification regressed)", file=sys.stderr)
        ok = False
    if breath["backend_ops"] >= breath["entries"]:
        print(f"FAIL: {breath['backend_ops']} backend ops for "
              f"{breath['entries']} manifest entries — the one-breath "
              "removal left the optimization window", file=sys.stderr)
        ok = False
    if breath["leftover"]:
        print(f"FAIL: {breath['leftover']} manifest entries survived the "
              "removal", file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
