"""CI regression guard for PR 4's dispatch hot path + same-breath bulk
removal.  Emits ``BENCH_pr4.json`` and FAILS (exit 1) when either
tentpole regressed.

Default mode is the **discrete-event simulation** (``SimClock``,
``core/simclock.py``): the benchmark driver and the executor's pool
workers run as actors of a cooperative event-queue simulation, so every
steal, park and fuse decision happens in token order and the whole
schedule — makespans, per-worker loads, op counts — is a pure function
of the workload manifest and the latency model's seed.  That buys two
things the old paced-real harness could not offer:

* the guard runs at ``REPRO_BENCH_SCALE=1.0`` in milliseconds of wall
  time (no real sleeps), and
* the bounds are *exact*: two same-seed runs produce byte-identical
  ``BENCH_pr4.json`` payloads, so thresholds need no scheduling slack.

1. **Dispatch scaling** — the extraction op stream runs at 1 worker and
   at 8 workers; ``SimClock.makespan()`` is the schedule's true critical
   path (idle gaps included, park handoffs and steal probes charged on
   the timeline).  With per-shard ready queues + work stealing the
   8-worker pool must clear >= 0.85x-ideal (6.8x) the single-worker
   throughput; a dispatch bottleneck (or a stealing bug starving
   shards) collapses the ratio.  Fusion is off so both runs execute the
   identical op count.

2. **Same-breath extract_then_rm** — extraction and readdir-driven
   removal in one breath: under the simulation the driver holds the run
   token through the whole submission burst, so *every* file op is
   still pending at fuse time and the collapse is total — the exact
   bound is ``n_dirs`` mkdirs (ordered under the fused removal by
   exec-time re-verification) plus ONE ``remove_tree``.

``--paced`` switches to the legacy paced-real smoke mode
(``PacedVirtualClock``: virtual accounting + scaled real sleeps, OS
scheduler decides interleaving): looser thresholds, nondeterministic
counts — keep it as a cheap cross-check that the simulation's story
survives contact with real threads, not as the blocking guard.

    PYTHONPATH=src REPRO_BENCH_SCALE=1.0 python -m benchmarks.dispatch_guard
    PYTHONPATH=src REPRO_BENCH_SCALE=0.1 python -m benchmarks.dispatch_guard --paced
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core import (CannyFS, InMemoryBackend, LatencyBackend,
                        LatencyModel, SimClock)

from .workloads import (PacedVirtualClock, TreeSpec, extract_then_rm,
                        extract_tree, synth_tree)

WORKERS = 8
#: sim schedules are deterministic — the 8-worker pool reliably lands
#: ~7.9x ideal-8x, so the floor is 0.85 x workers with no flake margin
MIN_SPEEDUP = {"sim": 0.85 * WORKERS, "paced": 2.0}


def dispatch_throughput(dirs, files, workers: int, mode: str) -> dict:
    clock = SimClock() if mode == "sim" else PacedVirtualClock()
    remote = LatencyBackend(
        InMemoryBackend(),
        LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.0, seed=4),
        clock=clock)
    fs = CannyFS(remote, max_inflight=4000, workers=workers,
                 fusion=False)   # fixed op count: pure dispatch measure
    extract_tree(fs, dirs, files)
    fs.close()
    st = fs.stats
    makespan = clock.makespan()
    return {
        "workers": workers,
        "ops": st.executed,
        "makespan_virtual_s": makespan,
        # per-worker virtual busy seconds: how evenly stealing spread the
        # load (under sim the makespan also covers idle gaps, so it can
        # exceed this list's max by the modelled park/steal overheads)
        "worker_virtual_s": sorted(clock.thread_seconds().values(),
                                   reverse=True),
        "ops_per_virtual_s": st.executed / makespan if makespan else 0.0,
        "steals": st.steals,
        "parks": st.parks,
        "ledger": len(fs.ledger),
    }


def same_breath_extract_rm(dirs, files, mode: str) -> dict:
    inner = InMemoryBackend()
    clock = SimClock() if mode == "sim" else None
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=3.0, data_ms=3.0, jitter_sigma=0.0,
                            server_slots=8, seed=9),
        **({"clock": clock} if clock is not None else {}))
    fs = CannyFS(remote, max_inflight=4000, workers=WORKERS)
    extract_then_rm(fs, dirs, files)
    fs.close()
    st = fs.stats
    snap = inner.snapshot()
    present = set(snap["files"]) | set(snap["dirs"])
    leftover = [p for p in (*dirs, *(p for p, _ in files)) if p in present]
    return {
        "entries": len(dirs) + len(files),    # the workload manifest
        "n_dirs": len(set(dirs)),
        "backend_ops": remote.op_count,
        "bulk_removes": st.bulk_removes,
        "bulk_reverify_promoted": st.bulk_reverify_promoted,
        "bulk_reverify_demoted": st.bulk_reverify_demoted,
        "elided_ops": st.elided_ops,
        "adaptive_max_bytes": st.adaptive_max_bytes,
        "leftover": len(leftover),
        "ledger": len(fs.ledger),
    }


def build_report(mode: str = "sim") -> dict:
    """Run both phases and return the full report payload (no I/O).  The
    determinism regression test calls this twice and asserts the sim
    payloads serialize byte-identically."""
    spec = TreeSpec(n_files=240, n_dirs=20).scaled()
    dirs, files = synth_tree(spec)
    one = dispatch_throughput(dirs, files, workers=1, mode=mode)
    eight = dispatch_throughput(dirs, files, workers=WORKERS, mode=mode)
    ratio = (eight["ops_per_virtual_s"] / one["ops_per_virtual_s"]
             if one["ops_per_virtual_s"] else 0.0)
    breath = same_breath_extract_rm(dirs, files, mode=mode)
    # sim: the driver's submission burst is one token-holding stretch, so
    # the whole manifest is pending at fuse time -> n_dirs mkdirs + one
    # remove_tree, exactly.  paced: workers race the driver, so only the
    # old "fewer ops than entries" sanity bound holds.
    max_breath_ops = (breath["n_dirs"] + 1 if mode == "sim"
                      else breath["entries"] - 1)
    return {
        "mode": mode,
        "dispatch": {"w1": one, "w8": eight, "speedup": ratio,
                     "min_speedup": MIN_SPEEDUP[mode]},
        "extract_then_rm": dict(breath, max_backend_ops=max_breath_ops),
    }


def check(report: dict) -> list[str]:
    """Return the list of FAIL strings for a report (empty == pass)."""
    mode = report["mode"]
    disp, breath = report["dispatch"], report["extract_then_rm"]
    one, eight, ratio = disp["w1"], disp["w8"], disp["speedup"]
    failures = []
    if ratio < disp["min_speedup"]:
        failures.append(
            f"{WORKERS}-worker dispatch throughput is {ratio:.2f}x the "
            f"single worker (need >= {disp['min_speedup']}x) — the sharded "
            "ready queues / work stealing regressed")
    if one["ledger"] or eight["ledger"] or breath["ledger"]:
        failures.append("deferred errors during a clean run")
    if breath["bulk_removes"] == 0:
        failures.append(
            "bulk_removes == 0 — the same-breath extract_then_rm workload "
            "no longer fuses its removal (exec-time re-verification "
            "regressed)")
    if breath["backend_ops"] > breath["max_backend_ops"]:
        bound = ("n_dirs + 1 (total same-breath collapse)" if mode == "sim"
                 else "the manifest entry count")
        failures.append(
            f"{breath['backend_ops']} backend ops for "
            f"{breath['entries']} manifest entries exceeds {bound} = "
            f"{breath['max_backend_ops']} — the one-breath removal left "
            "the optimization window")
    if breath["leftover"]:
        failures.append(
            f"{breath['leftover']} manifest entries survived the removal")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--paced", action="store_true",
                    help="legacy paced-real smoke mode (nondeterministic, "
                         "loose bounds) instead of the simulation")
    args = ap.parse_args(argv)
    mode = "paced" if args.paced else "sim"
    report = build_report(mode)
    with open("BENCH_pr4.json", "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    one, eight = report["dispatch"]["w1"], report["dispatch"]["w8"]
    breath = report["extract_then_rm"]
    print(f"[{mode}] dispatch: {one['ops']} ops  "
          f"w1={one['ops_per_virtual_s']:.0f}/s "
          f"w{WORKERS}={eight['ops_per_virtual_s']:.0f}/s  "
          f"speedup={report['dispatch']['speedup']:.2f}x "
          f"(steals={eight['steals']} parks={eight['parks']})")
    print(f"[{mode}] extract_then_rm: entries={breath['entries']} "
          f"backend_ops={breath['backend_ops']} "
          f"(bound {breath['max_backend_ops']}) "
          f"bulk_removes={breath['bulk_removes']} "
          f"reverify_promoted={breath['bulk_reverify_promoted']} "
          f"demoted={breath['bulk_reverify_demoted']}")
    failures = check(report)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
