"""Benchmark harness — one function per paper table/figure + the
beyond-paper training-I/O integration tables.

Prints ``name,us_per_call,derived`` CSV (harness contract).  Scale the
whole suite with REPRO_BENCH_SCALE (default 1.0; CI uses ~0.3).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only table1_extraction
"""
from __future__ import annotations

import argparse
import sys
import time


def _tables():
    from . import io_training, paper_tables
    return {
        # paper reproductions
        "table1_extraction": paper_tables.table1_extraction,
        "table1_removal": paper_tables.table1_removal,
        "fig24_variance": paper_tables.variance_under_load,
        "flag_ablation": paper_tables.flag_ablation,
        "budget_sweep": paper_tables.budget_sweep,
        "executor_modes": paper_tables.executor_modes,
        "rw_switch": paper_tables.rw_switch,
        "fusion": paper_tables.fusion_table,
        "backend": paper_tables.backend_table,
        "cold_walk": paper_tables.cold_walk_table,
        "read_ahead": paper_tables.read_ahead_table,
        "fault_recovery": paper_tables.fault_recovery,
        "multi_tenant": paper_tables.multi_tenant_table,
        # beyond-paper: the engine inside the training framework
        "checkpoint_stall": io_training.checkpoint_stall,
        "checkpoint_restore": io_training.checkpoint_restore,
        "metrics_stream": io_training.metrics_stream,
        "staged_data_read": io_training.staged_data_read,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    tables = _tables()
    names = args.only or list(tables)
    print("name,us_per_call,derived")
    t0 = time.monotonic()
    for name in names:
        fn = tables[name]
        try:
            rows = fn()
        except Exception as e:  # keep the suite running
            print(f"{name}/ERROR,0,{e!r}")
            continue
        for row in rows:
            print(",".join(str(c) for c in row))
        sys.stdout.flush()
    print(f"# total_bench_wall_s={time.monotonic() - t0:.1f}",
          file=sys.stderr)


if __name__ == "__main__":
    main()
