"""CI regression guard for the backend zoo + cost-model-driven fusion
(PR 8).  Emits ``BENCH_pr8.json`` and FAILS (exit 1) when the engine
stops collapsing work the way each storage medium's cost model demands.

Default mode is the **discrete-event simulation** (``SimClock``): both
new backends charge deterministic per-request latencies on a virtual
clock, so every counter below is a pure function of the workload
manifest and the guard runs at ``REPRO_BENCH_SCALE=1.0`` in
milliseconds of wall time with **zero slack**:

1. **Whole-object coalescing (object store)** — the chunked extraction
   must land exactly ONE whole-object PUT per manifest file and ZERO
   read-modify-write GETs; the fusion=False ablation pays one PUT per
   chunk plus one RMW GET for every chunk past a file's first (exact,
   manifest-derived).  On an object store coalescing is mandatory, not
   an optimization — a regression here multiplies both requests and
   egress bytes.

2. **Extract→rmtree collapse (object store)** — the same-breath
   workload must collapse to ``n_dirs`` marker PUTs plus ONE paginated
   LIST (``ceil(n_dirs / page)`` requests) plus ONE bulk DELETE —
   **never a DELETE per key**.  The direct ablation (all flags off)
   pays at least one request per manifest key and at least one DELETE
   per key, so the report's ``collapse_ratio`` is the paper's headline
   in request units.

3. **Remote cold walk (SFTP profile)** — the prefetch pipeline must
   meet walk_guard's manifest-derived roundtrip bound unchanged on
   ``RemoteStreamBackend``: ``ceil(dirs / batch) + depth + 1``
   round-trips, one per vectored frontier batch plus (worst case) one
   sync miss per spine level.  The cost hints size the batches; the
   vectored ops keep a batch ONE round-trip wide.

``--paced`` switches to the paced-real smoke (``PacedVirtualClock``):
real threads race, so chunk coalescing may split per file and a few
file ops may reach the wire before the removal fuses — the bounds relax
to "strictly beats the ablation" while the *semantic* invariants
(byte-identical extracted content, empty tree after removal, empty
ledger) stay exact.  Keep it as a non-blocking cross-check.

    PYTHONPATH=src REPRO_BENCH_SCALE=1.0 python -m benchmarks.backend_guard
    PYTHONPATH=src REPRO_BENCH_SCALE=0.1 python -m benchmarks.backend_guard --paced
"""
from __future__ import annotations

import argparse
import json
import math
import sys

from repro.core import CannyFS, EagerFlags, PrefetchPolicy, SimClock

from .workloads import (ColdTreeSpec, PacedVirtualClock, TreeSpec, cold_walk,
                        extract_then_rm, extract_tree_chunked,
                        make_object_store, make_remote_stream,
                        populate_cold_tree, synth_tree)

WORKERS = 8
CHUNK = 8192    # unzip's streaming write size
PAGE = 8        # small LIST page so pagination is actually exercised
BATCH = 16      # fixed prefetch width: the walk bound stays exact
PACE = 0.1
# paced mode only: the walker's sync misses can race in-flight batches
WALK_SLACK = {"sim": 0, "paced": 6}


def _store_counters(store) -> dict:
    return {
        "op_count": store.op_count,
        "request_count": store.request_count,
        "requests_by_class": dict(store.requests_by_class),
        "whole_object_puts": store.whole_object_puts,
        "rmw_gets": store.rmw_gets,
        "busy_s": store.busy_s,
    }


def _clock_for(mode: str):
    return SimClock() if mode == "sim" else PacedVirtualClock(pace=PACE)


def run_extract(dirs, files, *, fusion: bool, mode: str) -> dict:
    """Chunked extraction onto the object store; returns billing counters
    plus a byte-for-byte content check against the manifest."""
    store = make_object_store(clock=_clock_for(mode), list_page_size=PAGE)
    fs = CannyFS(store, max_inflight=4000, workers=WORKERS,
                 echo_errors=False, **({} if fusion else {"fusion": False}))
    extract_tree_chunked(fs, dirs, files, chunk=CHUNK)
    fs.close()
    snap = store.snapshot()
    content_ok = all(snap["files"].get(p) == data for p, data in files)
    return dict(_store_counters(store),
                fused_writes=fs.stats.fused_writes,
                content_ok=content_ok, ledger=len(fs.ledger))


def run_extract_rm(dirs, files, *, direct: bool, mode: str) -> dict:
    """Extraction + readdir-driven rmtree in one breath on the object
    store — fused, or the direct (all-flags-off) ablation."""
    store = make_object_store(clock=_clock_for(mode), list_page_size=PAGE)
    if direct:
        fs = CannyFS(store, flags=EagerFlags.all_off(), workers=2,
                     fusion=False, echo_errors=False)
    else:
        fs = CannyFS(store, max_inflight=4000, workers=WORKERS,
                     echo_errors=False)
    extract_then_rm(fs, dirs, files, chunk=CHUNK)
    fs.close()
    snap = store.snapshot()
    present = set(snap["files"]) | set(snap["dirs"])
    leftover = [p for p in (*dirs, *(p for p, _ in files)) if p in present]
    return dict(_store_counters(store),
                bulk_removes=fs.stats.bulk_removes,
                elided_ops=fs.stats.elided_ops,
                leftover=len(leftover), ledger=len(fs.ledger))


def run_remote_walk(spec: ColdTreeSpec, *, mode: str) -> dict:
    """walk_guard's cold-walk workload, re-run on the SFTP-shaped
    backend: same prefetch policy, same manifest-derived bound."""
    remote = make_remote_stream(clock=_clock_for(mode))
    dirs = populate_cold_tree(remote.inner, spec)   # bypass billing
    fs = CannyFS(remote, workers=WORKERS, echo_errors=False,
                 prefetch=PrefetchPolicy(adaptive_batch=False,
                                         max_batch=BATCH))
    visited = cold_walk(fs, spec.root)
    walk_ops = remote.op_count          # before close() lands stragglers
    fs.close()
    st = fs.stats
    return {
        "visited_dirs": visited,
        "manifest_dirs": len(dirs),
        "backend_ops_walk": walk_ops,
        "backend_ops_total": remote.op_count,
        "busy_s": remote.busy_s,
        "prefetch_batches": st.prefetch_batches,
        "prefetch_hits": st.prefetch_hits,
        "ledger": len(fs.ledger),
    }


def build_report(mode: str = "sim") -> dict:
    """Run all four workloads and return the payload (no I/O).  The
    determinism regression test calls this twice and asserts the sim
    payloads serialize byte-identically."""
    spec = TreeSpec(n_files=240, n_dirs=24).scaled()
    dirs, files = synth_tree(spec)
    n_dirs, n_files = len(dirs), len(files)
    total_chunks = sum(math.ceil(len(data) / CHUNK) for _, data in files)
    fused = run_extract(dirs, files, fusion=True, mode=mode)
    nofusion = run_extract(dirs, files, fusion=False, mode=mode)
    rm_fused = run_extract_rm(dirs, files, direct=False, mode=mode)
    rm_direct = run_extract_rm(dirs, files, direct=True, mode=mode)
    # the same-breath collapse, in wire requests: the mkdirs' marker PUTs
    # (ordered under the fused removal by exec-time re-verification) plus
    # the remove_tree's paginated LIST plus ONE bulk DELETE
    list_pages = math.ceil(n_dirs / PAGE)
    max_rm_requests = (n_dirs + list_pages + 1 if mode == "sim"
                       else rm_direct["request_count"] - 1)
    collapse = (rm_direct["request_count"] / rm_fused["request_count"]
                if rm_fused["request_count"] else 0.0)

    walk_spec = ColdTreeSpec().scaled()
    walk = run_remote_walk(walk_spec, mode=mode)
    max_walk_ops = (math.ceil(walk_spec.n_dirs() / BATCH) + walk_spec.depth
                    + 1 + WALK_SLACK[mode])
    return {
        "mode": mode,
        "object_store": {
            "spec": {"n_files": n_files, "n_dirs": n_dirs, "chunk": CHUNK,
                     "page": PAGE, "total_chunks": total_chunks,
                     "keys": n_dirs + n_files, "list_pages": list_pages},
            "extract_fused": fused,
            "extract_nofusion": nofusion,
            "extract_rm_fused": rm_fused,
            "extract_rm_direct": rm_direct,
            "max_rm_requests": max_rm_requests,
            "collapse_ratio": collapse,
        },
        "remote_walk": {
            "spec": {"fanout": walk_spec.fanout, "depth": walk_spec.depth,
                     "n_dirs": walk_spec.n_dirs(), "batch": BATCH},
            "walk": walk,
            "max_ops": max_walk_ops,
        },
    }


def check(report: dict) -> list[str]:
    """Return the list of FAIL strings for a report (empty == pass)."""
    mode = report["mode"]
    os_ = report["object_store"]
    spec = os_["spec"]
    fused, nofusion = os_["extract_fused"], os_["extract_nofusion"]
    rm_f, rm_d = os_["extract_rm_fused"], os_["extract_rm_direct"]
    failures = []

    for name, r in (("extract-fused", fused), ("extract-nofusion", nofusion),
                    ("extract-rm-fused", rm_f), ("extract-rm-direct", rm_d)):
        if r["ledger"]:
            failures.append(f"{name} left {r['ledger']} deferred errors on "
                            "a clean workload")
    for name, r in (("extract-fused", fused), ("extract-nofusion", nofusion)):
        if not r["content_ok"]:
            failures.append(f"{name} extracted content diverges from the "
                            "manifest — whole-object PUT semantics broke")

    # 1. whole-object coalescing
    if mode == "sim":
        if fused["whole_object_puts"] != spec["n_files"]:
            failures.append(
                f"fused extraction issued {fused['whole_object_puts']} "
                f"whole-object PUTs for {spec['n_files']} files — the "
                "cost-sized write coalescing no longer lands one PUT per "
                "object")
        if fused["rmw_gets"]:
            failures.append(
                f"fused extraction paid {fused['rmw_gets']} read-modify-"
                "write GETs — a write vector stopped covering its object")
        if nofusion["whole_object_puts"] != spec["total_chunks"]:
            failures.append(
                f"nofusion ablation issued {nofusion['whole_object_puts']} "
                f"PUTs for {spec['total_chunks']} chunks — the ablation is "
                "no longer chunk-per-request and the comparison is "
                "meaningless")
        if nofusion["rmw_gets"] != spec["total_chunks"] - spec["n_files"]:
            failures.append(
                f"nofusion ablation paid {nofusion['rmw_gets']} RMW GETs, "
                f"expected {spec['total_chunks'] - spec['n_files']} (every "
                "chunk past a file's first)")
    else:
        if not (spec["n_files"] <= fused["whole_object_puts"]
                < nofusion["whole_object_puts"]):
            failures.append(
                f"paced fused extraction issued "
                f"{fused['whole_object_puts']} PUTs vs the ablation's "
                f"{nofusion['whole_object_puts']} — coalescing never "
                "engaged under real threads")

    # 2. extract→rmtree collapse
    if rm_f["request_count"] > os_["max_rm_requests"]:
        bound = ("n_dirs + ceil(n_dirs/page) + 1 (marker PUTs + paginated "
                 "LIST + ONE bulk DELETE)" if mode == "sim"
                 else "the direct ablation's request count")
        failures.append(
            f"same-breath extract_rm issued {rm_f['request_count']} "
            f"requests, exceeding {bound} = {os_['max_rm_requests']} — "
            "the removal left the optimization window")
    if mode == "sim" and rm_f["requests_by_class"]["delete"] != 1:
        failures.append(
            f"same-breath extract_rm issued "
            f"{rm_f['requests_by_class']['delete']} DELETE requests — the "
            "fused remove_tree must be ONE bulk DELETE, never per-key")
    if mode == "sim" and rm_f["whole_object_puts"]:
        failures.append(
            f"{rm_f['whole_object_puts']} data PUTs reached the wire in "
            "the same-breath run — file chains stopped eliding")
    if rm_f["bulk_removes"] == 0:
        failures.append("bulk_removes == 0 — the removal never fused")
    if rm_f["leftover"] or rm_d["leftover"]:
        failures.append(
            f"manifest entries survived the removal (fused "
            f"{rm_f['leftover']}, direct {rm_d['leftover']})")
    if rm_d["request_count"] < spec["keys"]:
        failures.append(
            f"direct ablation issued {rm_d['request_count']} requests for "
            f"{spec['keys']} keys — eager collapse leaked into the "
            "all-flags-off run and the ratio is meaningless")
    if rm_d["requests_by_class"]["delete"] < spec["keys"]:
        failures.append(
            f"direct ablation issued {rm_d['requests_by_class']['delete']} "
            f"DELETEs for {spec['keys']} keys — per-key removal expected")

    # 3. remote cold walk
    rw = report["remote_walk"]
    walk = rw["walk"]
    if walk["visited_dirs"] != rw["spec"]["n_dirs"]:
        failures.append(
            f"remote walk visited {walk['visited_dirs']} dirs, manifest "
            f"lists {rw['spec']['n_dirs']} — traversal lost entries")
    if walk["ledger"]:
        failures.append(
            f"remote walk left {walk['ledger']} deferred errors on a "
            "read-only walk")
    if walk["backend_ops_total"] > rw["max_ops"]:
        failures.append(
            f"{walk['backend_ops_total']} round-trips for a cold walk of "
            f"{rw['spec']['n_dirs']} dirs exceeds the walk_guard bound "
            f"ceil(dirs/batch)+depth+1+slack = {rw['max_ops']} on the "
            "SFTP-shaped backend")
    if walk["prefetch_batches"] == 0:
        failures.append(
            "prefetch_batches == 0 — the pipeline never issued a vectored "
            "batch on the remote backend")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--paced", action="store_true",
                    help="paced-real smoke mode (nondeterministic, loose "
                         "bounds) instead of the simulation")
    args = ap.parse_args(argv)
    mode = "paced" if args.paced else "sim"
    report = build_report(mode)
    with open("BENCH_pr8.json", "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    os_ = report["object_store"]
    spec = os_["spec"]
    fused, nofusion = os_["extract_fused"], os_["extract_nofusion"]
    rm_f, rm_d = os_["extract_rm_fused"], os_["extract_rm_direct"]
    rw, walk = report["remote_walk"], report["remote_walk"]["walk"]
    print(f"[{mode}] object_store extract: files={spec['n_files']} "
          f"chunks={spec['total_chunks']}  "
          f"fused: puts={fused['whole_object_puts']} "
          f"rmw={fused['rmw_gets']} reqs={fused['request_count']}  "
          f"nofusion: puts={nofusion['whole_object_puts']} "
          f"rmw={nofusion['rmw_gets']} reqs={nofusion['request_count']}")
    print(f"[{mode}] extract_rm: keys={spec['keys']}  "
          f"fused: reqs={rm_f['request_count']} "
          f"(bound {os_['max_rm_requests']}) "
          f"deletes={rm_f['requests_by_class']['delete']}  "
          f"direct: reqs={rm_d['request_count']} "
          f"deletes={rm_d['requests_by_class']['delete']}  "
          f"collapse={os_['collapse_ratio']:.1f}x")
    print(f"[{mode}] remote_walk: dirs={rw['spec']['n_dirs']} "
          f"batch={BATCH}  ops={walk['backend_ops_total']} "
          f"(bound {rw['max_ops']}) batches={walk['prefetch_batches']} "
          f"hits={walk['prefetch_hits']}")
    failures = check(report)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
