"""Benchmark workloads: the paper's two model tasks, synthesized.

The paper extracts the Linux kernel zip (59,259 entries, ~2.1 GB, mean file
36 kB) onto NFS and then removes the tree.  We synthesize a tree with the
same shape statistics, scaled by REPRO_BENCH_SCALE so the suite stays
within CI budget, and replay it through three storage modes:

    cannyfs — eager engine, all ~20 flags on, budget 4000 (paper's setting)
    direct  — the same operation stream executed synchronously (NFS mode)
    staging — write to fast local store, then sequential copy-out
              (the tmpfs + rsync out-staging workflow)

Measurement harness (PR 6)
--------------------------

The guards measure on the **discrete-event simulation clock**
(``SimClock``, ``core/simclock.py``) by default: the driver and every
pool worker become actors of a cooperative event-queue simulation, so
the whole schedule — makespans, steal/park counts, per-worker loads,
fault firings — is a pure function of the workload manifest and the
latency model's seed.  Seed discipline therefore carries the entire
reproducibility story: every ``LatencyModel``/``FaultPlan`` in a
benchmark pins an explicit ``seed``, jitter is zero wherever a bound is
asserted, and byte-identical ``BENCH_*.json`` artifacts across
same-seed runs are themselves a CI regression check (same
``PYTHONHASHSEED``: the shard map hashes paths).  Sim mode runs at
``REPRO_BENCH_SCALE=1.0`` in milliseconds of wall time, so guard bounds
are exact manifest-derived quantities with zero scheduling slack.

``PacedVirtualClock`` remains as the opt-in **paced-real smoke mode**
(``--paced`` on the guards): scaled real sleeps under real OS
scheduling.  Use it as a periodic non-blocking cross-check that the
simulation's story survives contact with genuine threading (the
``test_sim_guards`` cross-validation automates the comparison at small
scale); use the simulation for anything that gates CI.
"""
from __future__ import annotations

import hashlib
import os
import time
from dataclasses import dataclass

import numpy as np

from repro.core import (CannyFS, EagerFlags, InMemoryBackend, LatencyBackend,
                        LatencyModel, ObjectStoreBackend, ObjectStoreModel,
                        RemoteStreamBackend, RemoteStreamModel, VirtualClock)


def bench_scale() -> float:
    return float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))


class PacedVirtualClock(VirtualClock):
    """Virtual accounting plus a real sleep scaled down by ``pace`` —
    the **opt-in smoke mode** (``--paced`` on the guards) since PR 6;
    the blocking guards measure on ``SimClock`` instead.

    The throughput *measure* stays virtual (per-thread makespan / total
    ``now()``), but a zero-real-cost op stream would leave the worker
    distribution to the OS scheduler: one GIL-holding worker can drain
    every shard before the parked ones wake, collapsing a measured
    speedup to ~1x on a bad scheduling roll — and a pipelined prefetch
    would never genuinely overlap its consumer.  The scaled real sleep
    makes each op genuinely block (releasing the GIL), so pools actually
    interleave and pipelines actually run ahead — at 1/20th real time, a
    1 ms modelled roundtrip costs 50 us of wall clock.  That buys
    realism, not determinism: counts and makespans still vary run to
    run, which is why its thresholds carry slack and it no longer gates
    CI (the discrete-event ``SimClock`` does, with exact bounds)."""

    def __init__(self, pace: float = 0.05):
        super().__init__()
        self.pace = pace

    def sleep(self, dt: float) -> None:
        super().sleep(dt)
        if dt > 0:
            time.sleep(dt * self.pace)


@dataclass(frozen=True)
class TreeSpec:
    n_files: int = 600
    n_dirs: int = 60
    mean_kb: float = 12.0     # scaled-down kernel tree
    seed: int = 42

    def scaled(self) -> "TreeSpec":
        s = bench_scale()
        return TreeSpec(max(int(self.n_files * s), 30),
                        max(int(self.n_dirs * s), 4),
                        self.mean_kb, self.seed)


def synth_tree(spec: TreeSpec):
    """-> (dirs, [(path, payload bytes)]) with kernel-like size skew."""
    rng = np.random.default_rng(spec.seed)
    dirs = ["src"]
    for i in range(spec.n_dirs - 1):
        parent = dirs[rng.integers(0, len(dirs))]
        dirs.append(f"{parent}/d{i:03d}")
    sizes = np.minimum(
        rng.lognormal(np.log(spec.mean_kb * 1024), 1.0,
                      spec.n_files).astype(int) + 64, 512 * 1024)
    payload = np.random.default_rng(1).integers(
        0, 256, size=int(sizes.max()), dtype=np.uint8).tobytes()
    files = []
    for i in range(spec.n_files):
        d = dirs[rng.integers(0, len(dirs))]
        files.append((f"{d}/f{i:05d}.c", payload[: sizes[i]]))
    return dirs, files


def make_remote_backend(load: float = 1.0, seed: int = 0,
                        jitter: float = 0.45, clock=None):
    """The paper's NFS-over-GbE under cluster load.  Pass
    ``clock=VirtualClock()`` to replay the same latency schedule without
    real sleeps (fault/chaos benchmarks and CI-budget runs)."""
    return LatencyBackend(
        InMemoryBackend(),
        LatencyModel(meta_ms=1.5, data_ms=1.5, bandwidth_mb_s=110.0,
                     jitter_sigma=jitter, server_slots=64, load=load,
                     seed=seed),
        clock=clock)


def make_object_store(clock=None, *, list_page_size: int = 1000,
                      rtt_ms: float = 25.0, per_request_ms: float = 2.0,
                      bandwidth_mb_s: float = 200.0) -> ObjectStoreBackend:
    """S3-shaped bottom of the stack: flat keyspace, paginated LIST,
    whole-object PUT, rename = COPY+DELETE.  Deterministic (no RNG) —
    billing is a pure function of the request stream."""
    return ObjectStoreBackend(
        model=ObjectStoreModel(rtt_ms=rtt_ms, per_request_ms=per_request_ms,
                               bandwidth_mb_s=bandwidth_mb_s,
                               list_page_size=list_page_size),
        clock=clock)


def make_remote_stream(clock=None, *, rtt_ms: float = 40.0,
                       per_item_ms: float = 0.5,
                       bandwidth_mb_s: float = 110.0) -> RemoteStreamBackend:
    """SFTP/WebDAV-shaped bottom of the stack: one high-RTT roundtrip per
    op, cheap streaming, native rename, vectored ops pipeline per-item."""
    return RemoteStreamBackend(
        model=RemoteStreamModel(rtt_ms=rtt_ms, per_item_ms=per_item_ms,
                                bandwidth_mb_s=bandwidth_mb_s),
        clock=clock)


# ---------------------------------------------------------------------------
# the three operation modes
# ---------------------------------------------------------------------------

def extract_tree(fs: CannyFS, dirs, files) -> None:
    """unzip-like replay: mkdir sweep, then create+write+utimens+chmod per
    file (the archive's metadata restore)."""
    for d in dirs:
        fs.makedirs(d)
    now = time.time()
    for path, data in files:
        with fs.open(path, "wb") as f:
            f.write(data)
        fs.utimens(path, now, now)
        fs.chmod(path, 0o644)


def extract_tree_chunked(fs: CannyFS, dirs, files, chunk: int = 8192) -> None:
    """The same replay with unzip's actual write pattern: each file is
    streamed through a bounded buffer, one write() per chunk.  Without the
    optimizer every chunk is a separate backend roundtrip; with it the
    chunks coalesce into one vectored write_vec per file."""
    for d in dirs:
        fs.makedirs(d)
    now = time.time()
    for path, data in files:
        with fs.open(path, "wb") as f:
            for lo in range(0, len(data), chunk):
                f.write(data[lo:lo + chunk])
        fs.utimens(path, now, now)
        fs.chmod(path, 0o644)


def remove_tree_manifest(fs: CannyFS, dirs, files) -> None:
    """rm -rf driven by the extractor's own manifest (no readdir): the
    removal shares the extract's unobserved window, so pending create+write
    chains are elided instead of ever reaching the backend — the paper's
    extract-then-delete workload at its transactional best."""
    for path, _ in files:
        fs.unlink(path)
    for d in sorted(dirs, key=lambda p: -p.count("/")):
        fs.rmdir(d)


def populate_tree(backend, dirs, files, payload_bytes: int = 64) -> int:
    """Materialize the tree directly on a backend (no engine, no latency):
    the pre-existing state a readdir-driven removal must discover.
    Returns the number of entries (dirs + files) created."""
    n = 0
    for d in dirs:
        try:
            backend.mkdir(d)
            n += 1
        except FileExistsError:
            pass
    for path, data in files:
        backend.create(path)
        backend.write_at(path, 0, data[:payload_bytes])
        n += 1
    return n


@dataclass(frozen=True)
class ColdTreeSpec:
    """A balanced cold tree for the ``cold_walk`` workload: ``fanout``
    subdirectories per directory to ``depth`` levels, ``files_per_dir``
    files in each.  The manifest (dirs, depth) is the source of truth
    for walk_guard's roundtrip bounds, so it must be exact."""

    fanout: int = 4
    depth: int = 4
    files_per_dir: int = 2
    root: str = "cold"

    def scaled(self) -> "ColdTreeSpec":
        # scale the fanout, keep the depth: the guard's pipelining story
        # is about breadth-per-level batches racing a depth-first walker
        s = bench_scale()
        return ColdTreeSpec(max(int(round(self.fanout * s)), 3),
                            self.depth, self.files_per_dir, self.root)

    def n_dirs(self) -> int:
        """Directories including the root: 1 + f + f^2 + ... + f^depth."""
        return sum(self.fanout ** k for k in range(self.depth + 1))


def synth_cold_tree(spec: ColdTreeSpec) -> list[str]:
    """The manifest: every directory path, parents before children."""
    level = [spec.root]
    dirs = [spec.root]
    for _ in range(spec.depth):
        nxt = []
        for parent in level:
            for i in range(spec.fanout):
                nxt.append(f"{parent}/s{i}")
        dirs.extend(nxt)
        level = nxt
    return dirs


def populate_cold_tree(backend, spec: ColdTreeSpec) -> list[str]:
    """Materialize the cold tree directly on a backend (no engine, no
    latency) — the pre-existing state a cold walk must discover."""
    dirs = synth_cold_tree(spec)
    for d in dirs:
        backend.mkdir(d)
        for j in range(spec.files_per_dir):
            backend.create(f"{d}/f{j}")
    return dirs


@dataclass(frozen=True)
class RestoreSpec:
    """A sharded checkpoint for the restore-read workloads: ``n_shards``
    files of ``shard_bytes`` each under ``root``, read back in ``chunk``-
    byte sequential slices.  The manifest (shards x bytes / chunk /
    window) is the source of truth for read_guard's roundtrip bounds, so
    it must be exact."""

    n_shards: int = 16
    shard_bytes: int = 1 << 20
    chunk: int = 64 << 10
    root: str = "ckpt"

    def scaled(self) -> "RestoreSpec":
        # scale the shard count, keep the per-shard stream: the guard's
        # pipelining story is windows racing ahead within each shard
        s = bench_scale()
        return RestoreSpec(max(int(round(self.n_shards * s)), 4),
                           self.shard_bytes, self.chunk, self.root)

    def total_bytes(self) -> int:
        return self.n_shards * self.shard_bytes


def _shard_payload(index: int, nbytes: int) -> bytes:
    """Deterministic, shard-distinct content (cross-shard mixups change
    the checksum)."""
    block = bytes((index * 131 + j) & 0xFF for j in range(256))
    return (block * (nbytes // 256 + 1))[:nbytes]


def populate_restore(backend, spec: RestoreSpec) -> list[str]:
    """Materialize the sharded checkpoint directly on a backend (no
    engine, no latency) — the cold state a restore must read back."""
    backend.mkdir(spec.root)
    paths = []
    for i in range(spec.n_shards):
        p = f"{spec.root}/shard_{i:04d}.bin"
        backend.create(p)
        backend.write_at(p, 0, _shard_payload(i, spec.shard_bytes))
        paths.append(p)
    return paths


def restore_read(fs: CannyFS, spec: RestoreSpec) -> tuple[int, str]:
    """The checkpoint-restore read storm: readdir the checkpoint dir,
    then stream every shard back in exact-size sequential chunks.  The
    per-shard size comes from ``stat`` (warmed by the readdir_plus
    listing — zero extra roundtrips) and the reader never reads past
    EOF, so the sync-path op count is a pure function of the manifest.
    Returns (total bytes, sha256 over shards in sorted order) — the
    caller cross-checks both against the ablation, byte for byte."""
    h = hashlib.sha256()
    total = 0
    for name in sorted(fs.readdir(spec.root)):
        p = f"{spec.root}/{name}"
        remaining = fs.stat(p).size
        with fs.open(p, "rb") as f:
            while remaining > 0:
                piece = f.read(min(spec.chunk, remaining))
                if not piece:
                    break
                h.update(piece)
                total += len(piece)
                remaining -= len(piece)
    return total, h.hexdigest()


def restore_read_interleaved(fs: CannyFS, spec: RestoreSpec,
                             rounds_limit: int | None = None) -> tuple[int,
                                                                       str]:
    """The restore *storm* access pattern: one driver round-robins a
    chunk from every shard per pass (what a sharded loader restoring N
    parameter shards concurrently looks like to the filesystem).  Each
    shard's stream stays sequential, so every shard keeps its own
    read-ahead pipeline in flight at once."""
    names = sorted(fs.readdir(spec.root))
    sizes = {n: fs.stat(f"{spec.root}/{n}").size for n in names}
    offsets = dict.fromkeys(names, 0)
    hashes = {n: hashlib.sha256() for n in names}
    total, live = 0, list(names)
    while live:
        nxt = []
        for n in live:
            take = min(spec.chunk, sizes[n] - offsets[n])
            piece = fs.pread(f"{spec.root}/{n}", offsets[n], take)
            if not piece:
                continue
            hashes[n].update(piece)
            offsets[n] += len(piece)
            total += len(piece)
            if offsets[n] < sizes[n]:
                nxt.append(n)
        live = nxt
        if rounds_limit is not None:
            rounds_limit -= 1
            if rounds_limit <= 0:
                break
    combined = hashlib.sha256()
    for n in names:
        combined.update(hashes[n].digest())
    return total, combined.hexdigest()


@dataclass(frozen=True)
class StreamSpec:
    """One large sequential file for the shard-stream workload."""

    file_bytes: int = 8 << 20
    chunk: int = 64 << 10
    path: str = "stream/seq.bin"

    def scaled(self) -> "StreamSpec":
        s = bench_scale()
        return StreamSpec(max(int(self.file_bytes * s), 1 << 20),
                          self.chunk, self.path)


def populate_stream(backend, spec: StreamSpec) -> None:
    backend.mkdir(spec.path.rsplit("/", 1)[0])
    backend.create(spec.path)
    backend.write_at(spec.path, 0, _shard_payload(7, spec.file_bytes))


def stream_read(fs: CannyFS, spec: StreamSpec) -> tuple[int, str]:
    """Sequential whole-file stream in exact-size chunks (one cold sync
    stat for the size, then never past EOF)."""
    h = hashlib.sha256()
    total = 0
    remaining = fs.stat(spec.path).size
    with fs.open(spec.path, "rb") as f:
        while remaining > 0:
            piece = f.read(min(spec.chunk, remaining))
            if not piece:
                break
            h.update(piece)
            total += len(piece)
            remaining -= len(piece)
    return total, h.hexdigest()


def cold_walk(fs: CannyFS, root: str = "cold") -> int:
    """Full traversal of a tree the mount has never observed — the cold
    metadata walk that opens both of the paper's model tasks.  Without
    the prefetch pipeline every directory costs one synchronous
    ``readdir_plus`` roundtrip, serialized by the recursion; with it the
    discovered frontier is fetched in batched ``readdir_plus_vec`` reads
    ahead of the walker.  Returns the number of directories visited (the
    caller cross-checks it against the manifest — no silent truncation)."""
    n = 0
    for _d, _subdirs, _files in fs.walk(root):
        n += 1
    return n


def rmtree_readdir(fs: CannyFS, root: str = "src") -> None:
    """rm -rf driven by readdir (the paper's actual removal benchmark and,
    pre-overlay, the engine's worst case: every readdir sealed the chains
    beneath it).  With the namespace overlay the listings come from
    cached/pending state, per-entry stats hit the warmed cache, and the
    bulk-remove pass collapses the unlinks+rmdirs into one remove_tree
    backend call per fused subtree."""
    fs.rmtree(root)


def extract_then_rm(fs: CannyFS, dirs, files, chunk: int = 8192) -> None:
    """Extract and readdir-driven rmtree in ONE breath — no drain between,
    so every mkdir is typically still pending when the removal walks the
    tree.  The paper's headline collapse at its hardest: file chains elide
    outright, readdirs answer from provisional overlay claims, and the
    rmdirs fuse into a single re-verified ``remove_tree`` backend call
    (exec-time promotion; pre-PR 4 the provisional mkdirs forced the
    per-entry fallback)."""
    extract_tree_chunked(fs, dirs, files, chunk=chunk)
    fs.rmtree("src")


def synth_tenant_tree(spec: TreeSpec, prefix: str):
    """The same kernel-shaped tree, rooted under ``prefix`` — one per
    tenant in the ``multi_tenant`` workload.  Distinct ``spec.seed`` per
    tenant gives each job its own shape draw."""
    dirs, files = synth_tree(spec)
    pdirs = [prefix] + [f"{prefix}/{d}" for d in dirs]
    pfiles = [(f"{prefix}/{p}", data) for p, data in files]
    return pdirs, pfiles


def tenant_job_steps(fs: CannyFS, prefix: str, dirs, files,
                     chunk: int = 8192, remove: bool = True):
    """One tenant's extract(+rmtree) job as a generator of steps.

    Yielding after every entry lets a single driver interleave N jobs
    round-robin — under ``SimClock`` that IS the deterministic model of N
    concurrent tenants sharing one engine (the sim driver holds the run
    token between yields), and under real threads each job can equally be
    drained straight through on its own thread.  Timestamps are fixed so
    the final backend state is a pure function of the manifest."""
    for d in dirs:
        fs.makedirs(d)
        yield
    for path, data in files:
        with fs.open(path, "wb") as f:
            for lo in range(0, len(data), chunk):
                f.write(data[lo:lo + chunk])
        fs.utimens(path, 1.0, 2.0)
        fs.chmod(path, 0o644)
        yield
    if remove:
        fs.rmtree(f"{prefix}/src")
        yield


def run_tenant_jobs(jobs) -> dict:
    """Round-robin the step generators to exhaustion.  A job whose step
    raises is dropped (its exception recorded) — one tenant's fault storm
    must not strand the driver loop.  Returns {name: error | None}."""
    outcomes = {name: None for name, _ in jobs}
    live = list(jobs)
    while live:
        nxt = []
        for name, gen in live:
            try:
                next(gen)
            except StopIteration:
                continue
            except Exception as e:          # noqa: BLE001 — chaos driver
                outcomes[name] = e
                continue
            nxt.append((name, gen))
        live = nxt
    return outcomes


def tenant_state_digest(backend_inner, prefix: str) -> str:
    """sha256 over the backend state at/under ``prefix`` (sorted paths,
    file contents, dirs, symlinks) — the byte-identical-to-solo check of
    the tenancy guard and chaos suite."""
    snap = backend_inner.snapshot()
    h = hashlib.sha256()
    pfx = prefix + "/"
    for p in sorted(snap.get("dirs", ())):
        if p == prefix or p.startswith(pfx):
            h.update(b"D" + p.encode() + b"\0")
    for p, data in sorted(snap.get("files", {}).items()):
        if p == prefix or p.startswith(pfx):
            h.update(b"F" + p.encode() + b"\0")
            h.update(hashlib.sha256(data).digest())
    for p, tgt in sorted(snap.get("symlinks", {}).items()):
        if p == prefix or p.startswith(pfx):
            h.update(b"L" + p.encode() + b"\0" + str(tgt).encode() + b"\0")
    return h.hexdigest()


def fusion_stats(fs: CannyFS) -> dict:
    """The optimizer's counters for one run, ready for a derived column."""
    st = fs.stats
    return {"fused_writes": st.fused_writes, "folded_meta": st.folded_meta,
            "elided_ops": st.elided_ops, "bytes_elided": st.bytes_elided,
            "overlay_readdirs": st.overlay_readdirs,
            "overlay_seals_avoided": st.overlay_seals_avoided,
            "bulk_removes": st.bulk_removes}


def run_extraction(mode: str, dirs, files, *, load: float = 1.0,
                   seed: int = 0, max_inflight: int = 4000,
                   workers: int = 64, executor: str = "pool") -> float:
    """Returns wall seconds until fully durable (mount closed + drained)."""
    remote = make_remote_backend(load=load, seed=seed)
    t0 = time.monotonic()
    if mode == "cannyfs":
        fs = CannyFS(remote, max_inflight=max_inflight, workers=workers,
                     executor=executor)
        extract_tree(fs, dirs, files)
        fs.close()
    elif mode == "direct":
        fs = CannyFS(remote, flags=EagerFlags.all_off(), workers=2)
        extract_tree(fs, dirs, files)
        fs.close()
    elif mode == "staging":
        local = CannyFS(InMemoryBackend(), flags=EagerFlags.all_off(),
                        workers=2)
        extract_tree(local, dirs, files)   # fast tmpfs phase
        local.close()
        # rsync -a like sequential copy-out (preserves times/modes)
        import time as _t
        now = _t.time()
        for d in dirs:
            try:
                remote.mkdir(d)
            except FileExistsError:
                pass
        for path, data in files:
            remote.create(path)
            remote.write_at(path, 0, data)
            remote.utimens(path, now, now)
            remote.chmod(path, 0o644)
    else:
        raise ValueError(mode)
    return time.monotonic() - t0


def run_removal(mode: str, dirs, files, *, load: float = 1.0,
                seed: int = 0, max_inflight: int = 4000,
                workers: int = 64) -> float:
    """rm -rf of a pre-populated tree."""
    remote = make_remote_backend(load=load, seed=seed)
    # populate instantly (bypasses latency: direct to inner store)
    inner = remote.inner
    for d in dirs:
        try:
            inner.mkdir(d)
        except FileExistsError:
            pass
    for path, data in files:
        inner.create(path)
        inner.write_at(path, 0, data[:64])
    t0 = time.monotonic()
    if mode == "cannyfs":
        fs = CannyFS(remote, max_inflight=max_inflight, workers=workers)
        fs.rmtree("src")
        fs.close()
    elif mode == "direct":
        fs = CannyFS(remote, flags=EagerFlags.all_off(), workers=2)
        fs.rmtree("src")
        fs.close()
    else:
        raise ValueError(mode)
    return time.monotonic() - t0
