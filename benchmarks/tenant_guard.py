"""CI regression guard for PR 10's multi-tenant serving layer.  Emits
``BENCH_pr10.json`` and FAILS (exit 1) when fairness or blast-radius
isolation regressed.

Default mode is the **discrete-event simulation** (``SimClock``): the
driver round-robins one step of every tenant's job between yields while
the pool workers run as sim actors, so the interleaving — and with it
every per-tenant makespan, credit spend and steal — is a pure function
of the manifest and the latency seed.  Two same-seed runs serialize
byte-identical ``BENCH_pr10.json`` payloads (asserted in
``tests/test_sim_guards.py``).

1. **Weighted fair dispatch** — N=4 equal-weight tenants run the
   extract+rmtree job concurrently on one engine.  Jain's fairness
   index over the per-tenant makespans must hold >= 0.9 (a starved
   tenant collapses it), and the slowest tenant (p99 at N=4) must
   finish within 1.5x the *fair share* of N serial runs — the summed
   solo makespans, i.e. what a perfectly fair processor-sharing engine
   would hand each tenant.

2. **Blast-radius isolation** — tenant t0 runs under a seeded fault
   storm (deterministic EIO burst + a scoped ``ProcessKilled``
   preemption via ``kill_scope="t0/*"``) while t1–t3 run clean.  The
   neighbours must end with EMPTY per-tenant ledgers and final backend
   state byte-identical to their solo runs on a private engine; the
   storm must stay visible in t0's ledger only.

``--paced`` switches to the paced-real smoke mode: one OS thread per
tenant over ``PacedVirtualClock`` — nondeterministic timings, loose
fairness floor, but the same hard isolation checks (neighbour digests
and ledgers are deterministic even under real scheduling).

    PYTHONPATH=src REPRO_BENCH_SCALE=1.0 python -m benchmarks.tenant_guard
    PYTHONPATH=src REPRO_BENCH_SCALE=0.1 python -m benchmarks.tenant_guard --paced
"""
from __future__ import annotations

import argparse
import json
import sys
import threading

from repro.core import (CannyFS, FaultInjectingBackend, FaultPlan, FaultRule,
                        InMemoryBackend, LatencyBackend, LatencyModel,
                        ProcessKilled, SimClock)

from .workloads import (PacedVirtualClock, TreeSpec, run_tenant_jobs,
                        synth_tenant_tree, tenant_job_steps,
                        tenant_state_digest)

N_TENANTS = 4
WORKERS = 8
MIN_JAIN = {"sim": 0.9, "paced": 0.5}
#: slowest tenant vs the fair share (summed solo makespans)
MAX_P99_RATIO = {"sim": 1.5, "paced": 3.0}


def _prefix(i: int) -> str:
    return f"t{i}"


def _tenant_spec(i: int) -> TreeSpec:
    # distinct seed per tenant: four different tree shapes, same scale
    return TreeSpec(n_files=120, n_dirs=12, seed=42 + i).scaled()


def _build_stack(mode: str, plan: FaultPlan | None = None,
                 kill_scope: str | None = None):
    clock = SimClock() if mode == "sim" else PacedVirtualClock()
    inner = InMemoryBackend()
    backend = LatencyBackend(
        inner, LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.0,
                            server_slots=32, seed=10),
        clock=clock)
    if plan is not None:
        backend = FaultInjectingBackend(backend, plan, clock=clock,
                                        kill_scope=kill_scope)
    return clock, inner, backend


def _run_concurrent(mode: str, *, remove: bool,
                    plan: FaultPlan | None = None,
                    kill_scope: str | None = None) -> dict:
    """N tenants on ONE engine: sim mode interleaves one driver round-
    robin (deterministic); paced mode runs one real thread per tenant."""
    clock, inner, backend = _build_stack(mode, plan, kill_scope)
    fs = CannyFS(backend, max_inflight=4000, workers=WORKERS,
                 echo_errors=False)
    tenants = [fs.tenant(_prefix(i), _prefix(i)) for i in range(N_TENANTS)]
    trees = [synth_tenant_tree(_tenant_spec(i), _prefix(i))
             for i in range(N_TENANTS)]
    if mode == "sim":
        jobs = [(_prefix(i),
                 tenant_job_steps(tenants[i], _prefix(i), *trees[i],
                                  remove=remove))
                for i in range(N_TENANTS)]
        outcomes = run_tenant_jobs(jobs)
    else:
        outcomes = {}

        def drive(i):
            try:
                for _ in tenant_job_steps(tenants[i], _prefix(i), *trees[i],
                                          remove=remove):
                    pass
            except Exception as e:          # noqa: BLE001 — chaos driver
                outcomes[_prefix(i)] = e

        threads = [threading.Thread(target=drive, args=(i,))
                   for i in range(N_TENANTS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    fs.close()
    st = fs.stats
    report = {
        "makespans": {name: ts.last_complete_s
                      for name, ts in st.tenants.items()},
        "tenants": {name: {
            "ops": ts.ops, "executed": ts.executed, "fused": ts.fused,
            "deferred_errors": ts.deferred_errors,
            "credits_spent": ts.credits_spent,
            "steals_served": ts.steals_served,
        } for name, ts in sorted(st.tenants.items())},
        "ledger_by_tenant": {
            _prefix(i): len(fs.ledger.entries_for_tenant(_prefix(i)))
            for i in range(N_TENANTS)},
        "digests": {_prefix(i): tenant_state_digest(inner, _prefix(i))
                    for i in range(N_TENANTS)},
        "admission_sheds": st.admission_sheds,
        "failed_jobs": sorted(k for k, v in outcomes.items()
                              if v is not None),
        # a tenant counts as killed when the scoped preemption reached its
        # ledger (the job itself is all-eager, so the driver's loop never
        # sees the raise — the deferred channel is the observation point)
        "killed_tenants": sorted(
            _prefix(i) for i in range(N_TENANTS)
            if any(isinstance(e.error, ProcessKilled)
                   for e in fs.ledger.entries_for_tenant(_prefix(i)))),
    }
    return report


def _run_solo(mode: str, i: int, *, remove: bool) -> dict:
    """The reference cell: tenant i alone on a private engine."""
    clock, inner, backend = _build_stack(mode)
    fs = CannyFS(backend, max_inflight=4000, workers=WORKERS,
                 echo_errors=False)
    tenant = fs.tenant(_prefix(i), _prefix(i))
    dirs, files = synth_tenant_tree(_tenant_spec(i), _prefix(i))
    for _ in tenant_job_steps(tenant, _prefix(i), dirs, files,
                              remove=remove):
        pass
    fs.close()
    ts = fs.stats.tenants[_prefix(i)]
    return {
        "makespan": ts.last_complete_s,
        "digest": tenant_state_digest(inner, _prefix(i)),
        "ledger": len(fs.ledger.entries_for_tenant(_prefix(i))),
    }


def _jain(xs) -> float:
    xs = list(xs)
    if not xs or not any(xs):
        return 0.0
    return (sum(xs) ** 2) / (len(xs) * sum(x * x for x in xs))


def _storm_plan() -> FaultPlan:
    """Deterministic t0-targeted storm: an EIO burst on writes, then a
    scoped kill — neighbours' paths never match.  Thresholds scale with
    the tenant's tree so the kill still fires at REPRO_BENCH_SCALE < 1."""
    n_files = _tenant_spec(0).n_files
    return FaultPlan([
        FaultRule(error="EIO", ops=("write",), path_glob="t0/*",
                  probability=1.0, after_count=max(2, n_files // 6),
                  max_failures=4),
        FaultRule(outcome="kill", path_glob="t0/*",
                  probability=1.0, after_count=max(6, n_files),
                  max_failures=1),
    ], seed=7)


def build_report(mode: str = "sim") -> dict:
    # fairness leg: clean extract+rmtree, concurrent vs N solo runs
    fair = _run_concurrent(mode, remove=True)
    solos = {_prefix(i): _run_solo(mode, i, remove=True)
             for i in range(N_TENANTS)}
    serial_total = sum(s["makespan"] for s in solos.values())
    makespans = sorted(fair["makespans"].values())
    p50 = makespans[len(makespans) // 2]
    p99 = makespans[-1]
    # isolation leg: extract only (non-trivial final state), t0 stormed
    iso = _run_concurrent(mode, remove=False, plan=_storm_plan(),
                          kill_scope="t0/*")
    iso_solo = {_prefix(i): _run_solo(mode, i, remove=False)
                for i in range(1, N_TENANTS)}
    return {
        "mode": mode,
        "n_tenants": N_TENANTS,
        "fairness": {
            "concurrent": fair,
            "solo_makespans": {k: s["makespan"] for k, s in solos.items()},
            "serial_total_s": serial_total,
            "jain": _jain(fair["makespans"].values()),
            "min_jain": MIN_JAIN[mode],
            "p50_makespan_s": p50,
            "p99_makespan_s": p99,
            "p99_over_fair_share": (p99 / serial_total if serial_total
                                    else 0.0),
            "max_p99_ratio": MAX_P99_RATIO[mode],
        },
        "isolation": {
            "storm": iso,
            "solo_digests": {k: s["digest"] for k, s in iso_solo.items()},
            "neighbour_ledgers": {k: iso["ledger_by_tenant"][k]
                                  for k in sorted(iso_solo)},
            "injected_tenant_ledger": iso["ledger_by_tenant"]["t0"],
        },
    }


def check(report: dict) -> list[str]:
    """Return the list of FAIL strings for a report (empty == pass)."""
    mode = report["mode"]
    fair, iso = report["fairness"], report["isolation"]
    failures = []
    if fair["jain"] < fair["min_jain"]:
        failures.append(
            f"Jain fairness index {fair['jain']:.3f} < {fair['min_jain']} "
            "over per-tenant makespans — DWRR dispatch is starving a "
            "tenant")
    if fair["p99_over_fair_share"] > fair["max_p99_ratio"]:
        failures.append(
            f"slowest tenant took {fair['p99_over_fair_share']:.2f}x the "
            f"fair share of {report['n_tenants']} serial runs "
            f"(limit {fair['max_p99_ratio']}x)")
    conc = fair["concurrent"]
    if any(conc["ledger_by_tenant"].values()) or conc["failed_jobs"]:
        failures.append("deferred errors or failed jobs in the clean "
                        "fairness run")
    for name, t in conc["tenants"].items():
        if mode == "sim" and t["credits_spent"] == 0:
            failures.append(f"tenant {name} spent no DWRR credits — fair "
                            "dispatch is not engaged")
    if iso["injected_tenant_ledger"] == 0:
        failures.append("the t0 fault storm left no ledger entries — the "
                        "isolation leg tested nothing")
    for name, n in iso["neighbour_ledgers"].items():
        if n != 0:
            failures.append(
                f"tenant {name} has {n} ledger entries from t0's fault "
                "storm — cross-tenant blast radius")
    for name, digest in iso["solo_digests"].items():
        if iso["storm"]["digests"][name] != digest:
            failures.append(
                f"tenant {name}'s final state diverged from its solo run "
                "while t0 was stormed — isolation broken")
    if iso["storm"]["killed_tenants"] != ["t0"]:
        failures.append(
            f"killed_tenants={iso['storm']['killed_tenants']} — the "
            "scoped preemption must reach exactly t0's ledger")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--paced", action="store_true",
                    help="paced-real smoke mode (one OS thread per tenant, "
                         "loose fairness floor) instead of the simulation")
    args = ap.parse_args(argv)
    mode = "paced" if args.paced else "sim"
    report = build_report(mode)
    with open("BENCH_pr10.json", "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    fair, iso = report["fairness"], report["isolation"]
    print(f"[{mode}] multi_tenant: N={report['n_tenants']} "
          f"jain={fair['jain']:.3f} "
          f"p99/fair={fair['p99_over_fair_share']:.2f}x "
          f"(serial_total={fair['serial_total_s']:.2f}s "
          f"sheds={fair['concurrent']['admission_sheds']})")
    print(f"[{mode}] isolation: t0_ledger={iso['injected_tenant_ledger']} "
          f"neighbour_ledgers={list(iso['neighbour_ledgers'].values())} "
          f"failed={iso['storm']['failed_jobs']}")
    failures = check(report)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
