"""CI regression guard for the speculative metadata prefetch pipeline
(PR 5).  Emits ``BENCH_pr5.json`` and FAILS (exit 1) when the pipelined
cold walk regressed:

1. **Roundtrip bound** — a cold walk of the ``cold_walk`` manifest must
   complete in at most ``ceil(dirs / batch) + depth`` LatencyBackend
   roundtrips (plus a small race slack): one vectored
   ``readdir_plus_vec`` per frontier batch, plus the walker's one sync
   miss per level of its depth-first spine before the pipeline catches
   up.  Without the prefetcher every directory is one sync roundtrip, so
   the bound is derived from the manifest (dirs, depth, batch width) and
   holds at any ``REPRO_BENCH_SCALE`` — a fixed threshold tuned at one
   scale would go vacuous (or spuriously red) at another.

2. **Virtual-time speedup** — the same walk with ``prefetch=False``
   (the ablation) must cost >= ``MIN_SPEEDUP``x the prefetch-on run's
   virtual I/O time (the latency model's total injected service,
   deterministic at zero jitter: op-count x RTT).

Latency is paced-virtual (``PacedVirtualClock``): the measure is
virtual, but each roundtrip also pays a scaled real sleep so the
speculative batches *genuinely* overlap the walker in wall time — on a
pure virtual clock the walker could drain the tree before the first
batch landed and the guard would flake on scheduling luck.

    PYTHONPATH=src REPRO_BENCH_SCALE=0.1 python -m benchmarks.walk_guard
"""
from __future__ import annotations

import json
import math
import sys

from repro.core import (CannyFS, InMemoryBackend, LatencyBackend,
                        LatencyModel, PrefetchPolicy)

from .workloads import (ColdTreeSpec, PacedVirtualClock, cold_walk,
                        populate_cold_tree)

MIN_SPEEDUP = 3.0
BATCH = 16          # fixed width so the manifest-derived bound is exact
META_MS = 40.0      # paced to 4 ms real per roundtrip: solid vs overhead
PACE = 0.1
# beyond one batch per ceil(dirs/BATCH) and one spine miss per level,
# tolerate a few duplicate fetches where the walker's sync miss raced a
# batch already carrying the same directory
OP_SLACK = 6


def run_walk(spec: ColdTreeSpec, *, prefetch: bool) -> dict:
    inner = InMemoryBackend()
    dirs = populate_cold_tree(inner, spec)
    clock = PacedVirtualClock(pace=PACE)
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=META_MS, data_ms=META_MS,
                            jitter_sigma=0.0, seed=5), clock=clock)
    policy = (PrefetchPolicy(adaptive_batch=False, max_batch=BATCH)
              if prefetch else False)
    fs = CannyFS(remote, workers=8, echo_errors=False, prefetch=policy)
    visited = cold_walk(fs, spec.root)
    walk_ops = remote.op_count          # before close() lands stragglers
    fs.close()
    st = fs.stats
    return {
        "visited_dirs": visited,
        "manifest_dirs": len(dirs),
        "backend_ops_walk": walk_ops,
        "backend_ops_total": remote.op_count,
        "virtual_io_s": clock.now(),
        "prefetch_issued": st.prefetch_issued,
        "prefetch_batches": st.prefetch_batches,
        "prefetch_hits": st.prefetch_hits,
        "prefetch_wasted": st.prefetch_wasted,
        "prefetch_cancelled": st.prefetch_cancelled,
        "overlay_readdirs": st.overlay_readdirs,
        "ledger": len(fs.ledger),
    }


def main() -> int:
    spec = ColdTreeSpec().scaled()
    n_dirs = spec.n_dirs()
    on = run_walk(spec, prefetch=True)
    off = run_walk(spec, prefetch=False)
    # the manifest-derived bound: batches + one spine miss per level
    # (the root's miss is level 0) + race slack
    max_ops = math.ceil(n_dirs / BATCH) + spec.depth + 1 + OP_SLACK
    speedup = (off["virtual_io_s"] / on["virtual_io_s"]
               if on["virtual_io_s"] else 0.0)
    report = {
        "cold_walk": {
            "spec": {"fanout": spec.fanout, "depth": spec.depth,
                     "files_per_dir": spec.files_per_dir,
                     "n_dirs": n_dirs, "batch": BATCH},
            "prefetch_on": on,
            "prefetch_off": off,
            "max_ops": max_ops,
            "speedup_virtual": speedup,
            "min_speedup": MIN_SPEEDUP,
        },
    }
    with open("BENCH_pr5.json", "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    print(f"cold_walk: dirs={n_dirs} depth={spec.depth} batch={BATCH}  "
          f"on: ops={on['backend_ops_total']} (bound {max_ops}) "
          f"virtual={on['virtual_io_s']:.2f}s  "
          f"off: ops={off['backend_ops_total']} "
          f"virtual={off['virtual_io_s']:.2f}s  speedup={speedup:.2f}x "
          f"(batches={on['prefetch_batches']} hits={on['prefetch_hits']} "
          f"wasted={on['prefetch_wasted']})")
    ok = True
    for name, r in (("prefetch-on", on), ("prefetch-off", off)):
        if r["visited_dirs"] != n_dirs:
            print(f"FAIL: {name} walk visited {r['visited_dirs']} dirs, "
                  f"manifest lists {n_dirs} — traversal lost entries",
                  file=sys.stderr)
            ok = False
        if r["ledger"]:
            print(f"FAIL: {name} run left {r['ledger']} deferred errors "
                  "on a read-only walk", file=sys.stderr)
            ok = False
    if on["backend_ops_total"] > max_ops:
        print(f"FAIL: {on['backend_ops_total']} roundtrips for a cold "
              f"walk of {n_dirs} dirs exceeds the manifest-derived bound "
              f"ceil(dirs/batch)+depth+slack = {max_ops} — the prefetch "
              "pipeline fell behind its consumer", file=sys.stderr)
        ok = False
    if on["prefetch_batches"] == 0:
        print("FAIL: prefetch_batches == 0 — the pipeline never issued a "
              "vectored batch on a cold walk", file=sys.stderr)
        ok = False
    if off["backend_ops_total"] < n_dirs:
        print(f"FAIL: the ablation walked {n_dirs} cold dirs in only "
              f"{off['backend_ops_total']} roundtrips — prefetch leaked "
              "into the prefetch=False run and the speedup below is "
              "meaningless", file=sys.stderr)
        ok = False
    if speedup < MIN_SPEEDUP:
        print(f"FAIL: prefetch-on virtual I/O time is only {speedup:.2f}x "
              f"better than the ablation (need >= {MIN_SPEEDUP}x)",
              file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
