"""CI regression guard for the speculative metadata prefetch pipeline
(PR 5).  Emits ``BENCH_pr5.json`` and FAILS (exit 1) when the pipelined
cold walk regressed.

Default mode is the **discrete-event simulation** (``SimClock``): the
walker and pool workers are actors of a cooperative event-queue
simulation, so whether a speculative batch lands before the walker's
next sync miss is decided by *modelled* latencies in token order — a
pure function of the manifest and the model's seed — instead of by OS
scheduling luck.  The guard therefore runs at ``REPRO_BENCH_SCALE=1.0``
in milliseconds of wall time, with **zero slack** on the roundtrip
bound and a speedup floor *derived from that bound*:

1. **Roundtrip bound** — a cold walk of the ``cold_walk`` manifest must
   complete in at most ``ceil(dirs / batch) + depth + 1`` LatencyBackend
   roundtrips: one vectored ``readdir_plus_vec`` per frontier batch,
   plus (worst case) one sync miss per level of the walker's
   depth-first spine before the pipeline catches up.  No race slack —
   the simulated schedule either meets the bound or regressed.

2. **Virtual-time speedup** — the same walk with ``prefetch=False``
   (the ablation) costs exactly one roundtrip per directory, so the
   total injected service must improve by at least
   ``n_dirs / max_ops`` — the op-count collapse the bound guarantees.

``--paced`` switches to the legacy paced-real smoke
(``PacedVirtualClock``: each roundtrip pays a scaled real sleep so the
batches genuinely overlap the walker in wall time): loose slack, fixed
3x floor — keep it as a non-blocking cross-check that the pipeline
still overlaps under real threading, not as the blocking guard.

    PYTHONPATH=src REPRO_BENCH_SCALE=1.0 python -m benchmarks.walk_guard
    PYTHONPATH=src REPRO_BENCH_SCALE=0.1 python -m benchmarks.walk_guard --paced
"""
from __future__ import annotations

import argparse
import json
import math
import sys

from repro.core import (CannyFS, InMemoryBackend, LatencyBackend,
                        LatencyModel, PrefetchPolicy, SimClock)

from .workloads import (ColdTreeSpec, PacedVirtualClock, cold_walk,
                        populate_cold_tree)

MIN_SPEEDUP_PACED = 3.0
BATCH = 16          # fixed width so the manifest-derived bound is exact
META_MS = 40.0      # paced mode: 4 ms real per roundtrip; sim: pure virtual
PACE = 0.1
# paced mode only: tolerate a few duplicate fetches where the walker's
# sync miss raced a batch already carrying the same directory.  The sim
# schedule has no such races — its slack is zero.
OP_SLACK = {"sim": 0, "paced": 6}


def run_walk(spec: ColdTreeSpec, *, prefetch: bool, mode: str) -> dict:
    inner = InMemoryBackend()
    dirs = populate_cold_tree(inner, spec)
    clock = SimClock() if mode == "sim" else PacedVirtualClock(pace=PACE)
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=META_MS, data_ms=META_MS,
                            jitter_sigma=0.0, seed=5), clock=clock)
    policy = (PrefetchPolicy(adaptive_batch=False, max_batch=BATCH)
              if prefetch else False)
    fs = CannyFS(remote, workers=8, echo_errors=False, prefetch=policy)
    visited = cold_walk(fs, spec.root)
    walk_ops = remote.op_count          # before close() lands stragglers
    fs.close()
    st = fs.stats
    # total injected service: every roundtrip's modelled latency summed
    # over whichever thread paid it — PacedVirtualClock accumulates it
    # globally, SimClock per actor
    virtual_io = (sum(clock.thread_seconds().values()) if mode == "sim"
                  else clock.now())
    return {
        "visited_dirs": visited,
        "manifest_dirs": len(dirs),
        "backend_ops_walk": walk_ops,
        "backend_ops_total": remote.op_count,
        "virtual_io_s": virtual_io,
        # sim only: the schedule's true critical path (idle included) —
        # how long the walk *takes*, not how much service it buys
        "makespan_virtual_s": clock.makespan(),
        "prefetch_issued": st.prefetch_issued,
        "prefetch_batches": st.prefetch_batches,
        "prefetch_hits": st.prefetch_hits,
        "prefetch_wasted": st.prefetch_wasted,
        "prefetch_cancelled": st.prefetch_cancelled,
        "overlay_readdirs": st.overlay_readdirs,
        "ledger": len(fs.ledger),
    }


def build_report(mode: str = "sim") -> dict:
    """Run the prefetch-on walk and its ablation; return the payload (no
    I/O).  The determinism regression test calls this twice and asserts
    the sim payloads serialize byte-identically."""
    spec = ColdTreeSpec().scaled()
    n_dirs = spec.n_dirs()
    on = run_walk(spec, prefetch=True, mode=mode)
    off = run_walk(spec, prefetch=False, mode=mode)
    # the manifest-derived bound: batches + one spine miss per level
    # (the root's miss is level 0) + mode-dependent race slack
    max_ops = math.ceil(n_dirs / BATCH) + spec.depth + 1 + OP_SLACK[mode]
    # the ablation pays one roundtrip per dir, the pipeline at most
    # max_ops — so the sim speedup floor IS the op-count collapse
    min_speedup = (n_dirs / max_ops if mode == "sim" else MIN_SPEEDUP_PACED)
    speedup = (off["virtual_io_s"] / on["virtual_io_s"]
               if on["virtual_io_s"] else 0.0)
    return {
        "mode": mode,
        "cold_walk": {
            "spec": {"fanout": spec.fanout, "depth": spec.depth,
                     "files_per_dir": spec.files_per_dir,
                     "n_dirs": n_dirs, "batch": BATCH},
            "prefetch_on": on,
            "prefetch_off": off,
            "max_ops": max_ops,
            "speedup_virtual": speedup,
            "min_speedup": min_speedup,
        },
    }


def check(report: dict) -> list[str]:
    """Return the list of FAIL strings for a report (empty == pass)."""
    cw = report["cold_walk"]
    on, off = cw["prefetch_on"], cw["prefetch_off"]
    n_dirs, max_ops = cw["spec"]["n_dirs"], cw["max_ops"]
    failures = []
    for name, r in (("prefetch-on", on), ("prefetch-off", off)):
        if r["visited_dirs"] != n_dirs:
            failures.append(
                f"{name} walk visited {r['visited_dirs']} dirs, manifest "
                f"lists {n_dirs} — traversal lost entries")
        if r["ledger"]:
            failures.append(
                f"{name} run left {r['ledger']} deferred errors on a "
                "read-only walk")
    if on["backend_ops_total"] > max_ops:
        failures.append(
            f"{on['backend_ops_total']} roundtrips for a cold walk of "
            f"{n_dirs} dirs exceeds the manifest-derived bound "
            f"ceil(dirs/batch)+depth+1+slack = {max_ops} — the prefetch "
            "pipeline fell behind its consumer")
    if on["prefetch_batches"] == 0:
        failures.append(
            "prefetch_batches == 0 — the pipeline never issued a vectored "
            "batch on a cold walk")
    if off["backend_ops_total"] < n_dirs:
        failures.append(
            f"the ablation walked {n_dirs} cold dirs in only "
            f"{off['backend_ops_total']} roundtrips — prefetch leaked into "
            "the prefetch=False run and the speedup is meaningless")
    if cw["speedup_virtual"] < cw["min_speedup"]:
        failures.append(
            f"prefetch-on virtual I/O time is only "
            f"{cw['speedup_virtual']:.2f}x better than the ablation "
            f"(need >= {cw['min_speedup']:.2f}x)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--paced", action="store_true",
                    help="legacy paced-real smoke mode (nondeterministic, "
                         "loose bounds) instead of the simulation")
    args = ap.parse_args(argv)
    mode = "paced" if args.paced else "sim"
    report = build_report(mode)
    with open("BENCH_pr5.json", "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    cw = report["cold_walk"]
    on, off = cw["prefetch_on"], cw["prefetch_off"]
    print(f"[{mode}] cold_walk: dirs={cw['spec']['n_dirs']} "
          f"depth={cw['spec']['depth']} batch={BATCH}  "
          f"on: ops={on['backend_ops_total']} (bound {cw['max_ops']}) "
          f"virtual={on['virtual_io_s']:.2f}s "
          f"makespan={on['makespan_virtual_s']:.2f}s  "
          f"off: ops={off['backend_ops_total']} "
          f"virtual={off['virtual_io_s']:.2f}s  "
          f"speedup={cw['speedup_virtual']:.2f}x "
          f"(floor {cw['min_speedup']:.2f}x, "
          f"batches={on['prefetch_batches']} hits={on['prefetch_hits']} "
          f"wasted={on['prefetch_wasted']})")
    failures = check(report)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
