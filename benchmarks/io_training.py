"""Beyond-paper benchmarks: the CannyFS engine integrated into the training
framework's I/O paths (checkpoint stall, staged data, metrics stream)."""
from __future__ import annotations

import time

import numpy as np

from repro.checkpoint import TransactionalCheckpointManager
from repro.core import CannyFS, EagerFlags

from .workloads import (RestoreSpec, bench_scale, make_remote_backend,
                        populate_restore, restore_read)


def _fake_state(mb: float) -> dict:
    n = int(mb * 1024 * 1024 / 4)
    rng = np.random.default_rng(0)
    return {"params": {"w": rng.standard_normal(n // 2).astype(np.float32),
                       "u": rng.standard_normal(n // 2).astype(np.float32)},
            "step": np.asarray(1, np.int32)}


def checkpoint_stall(state_mb: float = 64.0, steps: int = 8,
                     step_time_s: float = 0.15) -> list:
    """Train-loop stall per checkpoint: synchronous vs transactional-async.

    A fake train loop 'computes' for step_time_s per step and checkpoints
    every other step; measured is total wall time and per-save stall."""
    state_mb *= max(bench_scale(), 0.1)
    state = _fake_state(state_mb)
    rows = []
    for mode in ("transactional", "sync"):
        remote = make_remote_backend(load=2.0, seed=5, jitter=0.0)
        if mode == "transactional":
            fs = CannyFS(remote, max_inflight=4000, workers=64)
        else:
            fs = CannyFS(remote, flags=EagerFlags.all_off(), workers=2)
        mgr = TransactionalCheckpointManager(fs, "ckpt", keep=2)
        stalls = []
        t0 = time.monotonic()
        for s in range(steps):
            time.sleep(step_time_s)           # the 'compute'
            if s % 2 == 1:
                ts = time.monotonic()
                mgr.save(s, state, block=(mode == "sync"))
                stalls.append(time.monotonic() - ts)
        mgr.wait_for_save()
        total = time.monotonic() - t0
        fs.close()
        n_saves = len(stalls)
        rows.append((f"ckpt_stall/{mode}",
                     f"{np.mean(stalls) * 1e6:.0f}",
                     f"stall_per_save={np.mean(stalls):.3f}s;"
                     f"total={total:.2f}s;saves={n_saves};"
                     f"state_mb={state_mb:.0f}"))
    return rows


def checkpoint_restore(n_shards: int = 16) -> list:
    """Job-start restore stall: stream a sharded checkpoint back through
    the read-ahead plane vs one sync roundtrip per chunk.

    The mirror image of ``checkpoint_stall``: saves hide behind deferred
    writes, but a restore *must* block on every byte — the only lever is
    pipelining the reads.  The sharded checkpoint sits on the remote
    backend cold; both modes read it back chunked and verify the same
    checksum (the plane is an optimization, never a semantics change)."""
    spec = RestoreSpec(n_shards=n_shards).scaled()
    rows = []
    digests = {}
    for mode in ("cannyfs", "direct"):
        remote = make_remote_backend(load=1.0, seed=17, jitter=0.0)
        populate_restore(remote.inner, spec)    # cold state, bypass latency
        if mode == "cannyfs":
            fs = CannyFS(remote, max_inflight=4000, workers=16)
        else:
            fs = CannyFS(remote, flags=EagerFlags.all_off(), workers=2,
                         readahead=False)
        t0 = time.monotonic()
        nbytes, digest = restore_read(fs, spec)
        t = time.monotonic() - t0
        fs.close()
        digests[mode] = (nbytes, digest)
        st = fs.stats
        rows.append((f"ckpt_restore/{mode}",
                     f"{t / spec.n_shards * 1e6:.0f}",
                     f"total={t:.2f}s;shards={spec.n_shards};"
                     f"bytes={nbytes};backend_ops={remote.op_count};"
                     f"ra_windows={st.readahead_windows};"
                     f"ra_hits={st.readahead_hits};"
                     f"ra_wasted={st.readahead_wasted}"))
    assert digests["cannyfs"] == digests["direct"], digests
    return rows


def metrics_stream(n: int = 2000) -> list:
    """Append-only metrics stream through the eager engine vs sync."""
    from repro.train.metrics import MetricsWriter
    n = max(int(n * bench_scale()), 100)
    rows = []
    for mode in ("cannyfs", "direct"):
        remote = make_remote_backend(load=1.0, seed=9, jitter=0.0)
        flags = EagerFlags() if mode == "cannyfs" else EagerFlags.all_off()
        fs = CannyFS(remote, flags=flags, max_inflight=4000, workers=16)
        w = MetricsWriter(fs)
        t0 = time.monotonic()
        for i in range(n):
            w.write(i, {"loss": 1.0 / (i + 1), "lr": 3e-4})
        t_ack = time.monotonic() - t0
        w.close()
        fs.close()
        t_total = time.monotonic() - t0
        rows.append((f"metrics/{mode}", f"{t_ack / n * 1e6:.0f}",
                     f"ack_total={t_ack:.2f}s;durable_total={t_total:.2f}s;"
                     f"n={n}"))
    return rows


def staged_data_read(n_shards: int = 20) -> list:
    """Shard-sweep read with readdir prefetch vs sync stat+read."""
    from repro.core import InMemoryBackend
    n_shards = max(int(n_shards * bench_scale()), 4)
    payload = np.random.default_rng(2).bytes(256 * 1024)
    rows = []
    for mode in ("cannyfs", "direct"):
        remote = make_remote_backend(load=1.0, seed=13, jitter=0.0)
        inner = remote.inner
        inner.mkdir("shards")
        for i in range(n_shards):
            inner.create(f"shards/s{i:04d}.bin")
            inner.write_at(f"shards/s{i:04d}.bin", 0, payload)
        flags = EagerFlags() if mode == "cannyfs" else EagerFlags.all_off()
        fs = CannyFS(remote, flags=flags, max_inflight=4000, workers=32)
        t0 = time.monotonic()
        total = 0
        for name in fs.readdir("shards"):
            st = fs.stat(f"shards/{name}")   # prefetched in cannyfs mode
            total += st.size
            fs.read_file(f"shards/{name}")
        t = time.monotonic() - t0
        fs.close()
        rows.append((f"staged_read/{mode}", f"{t / n_shards * 1e6:.0f}",
                     f"total={t:.2f}s;shards={n_shards};bytes={total}"))
    return rows
