"""CI regression guard for the namespace overlay + bulk-remove pass.

Runs the ``rmtree_readdir`` workload (readdir-driven removal of a
pre-existing tree — the engine's pre-overlay worst case) with the
overlay enabled and FAILS (exit 1) if the optimization regressed.

Default mode is the **discrete-event simulation** (``SimClock``): the
driver and workers are actors of a cooperative event-queue simulation,
so whether a pending unlink is still in the optimization window when
its directory's rmdir arrives is decided by modelled latencies in token
order — deterministic, at ``REPRO_BENCH_SCALE=1.0``, in milliseconds of
wall time.  That lets the op bound drop from the paced harness's
``2 * n_dirs + slack`` to ``n_dirs + slack``: the cold listings arrive
in vectored prefetch batches (far fewer than one per dir) and the
removals collapse into a handful of fused ``remove_tree`` calls, so
one-op-per-dir already has every structural cost covered with room to
spare.

``--paced`` keeps the legacy real-latency smoke: small real sleeps so
the remote queue genuinely backs up and pending removals outlive the
walk under real threading.  Looser bound (races can demote fusions) —
run it as a non-blocking cross-check, not the blocking guard.

    PYTHONPATH=src REPRO_BENCH_SCALE=1.0 python -m benchmarks.overlay_guard
    PYTHONPATH=src REPRO_BENCH_SCALE=0.1 python -m benchmarks.overlay_guard --paced
"""
from __future__ import annotations

import argparse
import sys

from repro.core import (CannyFS, InMemoryBackend, LatencyBackend,
                        LatencyModel, SimClock)

from .workloads import TreeSpec, populate_tree, rmtree_readdir, synth_tree

WORKERS = 4
# paced: beyond one listing per dir + one fused removal per dir, tolerate
# a few stray sync stats plus the removals each worker may claim in the
# instant between a dir's unlinks being admitted and its rmdir collapsing
# them.  sim: no scheduling races — a token-order schedule leaves only a
# fixed handful of structural ops (root miss, batch fetches, fused
# removes), all inside n_dirs + 4.
OP_SLACK = {"sim": 4, "paced": 4 + 2 * WORKERS}


def build_report(mode: str = "sim") -> dict:
    """Run the workload and return the report payload (no I/O)."""
    spec = TreeSpec(n_files=200, n_dirs=16).scaled()
    dirs, files = synth_tree(spec)
    # the workload manifest is the source of truth for every bound below
    n_dirs, n_files = len(set(dirs)), len(files)
    entries = n_dirs + n_files
    inner = InMemoryBackend()
    populated = populate_tree(inner, dirs, files)
    clock = SimClock() if mode == "sim" else None
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.0,
                            seed=3),
        **({"clock": clock} if clock is not None else {}))
    fs = CannyFS(remote, max_inflight=4000, workers=WORKERS)
    rmtree_readdir(fs, "src")
    fs.close()
    st = fs.stats
    snap = inner.snapshot()
    gone = set(snap["files"]) | set(snap["dirs"])
    leftover = [p for p in (*dirs, *(p for p, _ in files)) if p in gone]
    max_ops = ((n_dirs if mode == "sim" else 2 * n_dirs)
               + OP_SLACK[mode])
    return {
        "mode": mode,
        "entries": entries,
        "n_dirs": n_dirs,
        "n_files": n_files,
        "populated": populated,
        "backend_ops": remote.op_count,
        "max_ops": max_ops,
        "bulk_removes": st.bulk_removes,
        "overlay_readdirs": st.overlay_readdirs,
        "elided_ops": st.elided_ops,
        "makespan_virtual_s": clock.makespan() if clock is not None else None,
        "leftover": len(leftover),
        "ledger": len(fs.ledger),
    }


def check(report: dict) -> list[str]:
    """Return the list of FAIL strings for a report (empty == pass)."""
    failures = []
    if report["populated"] != report["entries"]:
        failures.append(
            f"populated {report['populated']} entries but the manifest "
            f"lists {report['entries']} — workload generation drifted")
        return failures
    if report["bulk_removes"] == 0:
        failures.append(
            "bulk_removes == 0 — the cross-path bulk-remove pass did not "
            "fire on the overlay-enabled run")
    if report["backend_ops"] > report["max_ops"]:
        failures.append(
            f"{report['backend_ops']} backend ops exceeds the "
            f"manifest-derived bound {report['max_ops']} — readdir-driven "
            "rmtree left the optimization window")
    if report["leftover"]:
        failures.append(
            f"{report['leftover']} manifest entries survived the removal")
    if report["ledger"]:
        failures.append("deferred errors during a clean removal")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--paced", action="store_true",
                    help="legacy real-latency smoke mode (nondeterministic, "
                         "loose bounds) instead of the simulation")
    args = ap.parse_args(argv)
    mode = "paced" if args.paced else "sim"
    report = build_report(mode)
    print(f"[{mode}] rmtree_readdir: entries={report['entries']} "
          f"(dirs={report['n_dirs']} files={report['n_files']}) "
          f"backend_ops={report['backend_ops']} "
          f"max_ops={report['max_ops']} "
          f"bulk_removes={report['bulk_removes']} "
          f"overlay_readdirs={report['overlay_readdirs']} "
          f"elided_ops={report['elided_ops']} ledger={report['ledger']}")
    failures = check(report)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
