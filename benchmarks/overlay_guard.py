"""CI regression guard for the namespace overlay + bulk-remove pass.

Runs the ``rmtree_readdir`` workload (readdir-driven removal of a
pre-existing tree — the engine's pre-overlay worst case) with the overlay
enabled and FAILS (exit 1) if the optimization regressed:

* ``bulk_removes == 0`` — the cross-path pass never fired, or
* ``backend_ops >= entries`` — the removal degenerated back to one
  backend op per entry.

Scale with REPRO_BENCH_SCALE as usual (CI runs 0.1).

    PYTHONPATH=src REPRO_BENCH_SCALE=0.1 python -m benchmarks.overlay_guard
"""
from __future__ import annotations

import sys

from repro.core import CannyFS, InMemoryBackend, LatencyBackend, LatencyModel, VirtualClock

from .workloads import TreeSpec, populate_tree, rmtree_readdir, synth_tree


def main() -> int:
    spec = TreeSpec(n_files=200, n_dirs=16).scaled()
    dirs, files = synth_tree(spec)
    inner = InMemoryBackend()
    entries = populate_tree(inner, dirs, files)
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.0,
                            seed=3),
        clock=VirtualClock())   # deterministic, no real sleeps in CI
    fs = CannyFS(remote, max_inflight=4000, workers=16)
    rmtree_readdir(fs, "src")
    fs.close()
    st = fs.stats
    leftover = [p for pool in ("files", "dirs")
                for p in inner.snapshot()[pool] if str(p).startswith("src")]
    print(f"rmtree_readdir: entries={entries} backend_ops={remote.op_count} "
          f"bulk_removes={st.bulk_removes} "
          f"overlay_readdirs={st.overlay_readdirs} "
          f"elided_ops={st.elided_ops} ledger={len(fs.ledger)}")
    ok = True
    if st.bulk_removes == 0:
        print("FAIL: bulk_removes == 0 — the cross-path bulk-remove pass "
              "did not fire on the overlay-enabled run", file=sys.stderr)
        ok = False
    if remote.op_count >= entries:
        print(f"FAIL: {remote.op_count} backend ops for {entries} entries — "
              "readdir-driven rmtree left the optimization window",
              file=sys.stderr)
        ok = False
    if leftover:
        print(f"FAIL: {len(leftover)} entries survived the removal",
              file=sys.stderr)
        ok = False
    if len(fs.ledger):
        print("FAIL: deferred errors during a clean removal", file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
