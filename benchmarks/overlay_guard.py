"""CI regression guard for the namespace overlay + bulk-remove pass.

Runs the ``rmtree_readdir`` workload (readdir-driven removal of a
pre-existing tree — the engine's pre-overlay worst case) with the overlay
enabled and FAILS (exit 1) if the optimization regressed:

* ``bulk_removes == 0`` — the cross-path pass never fired, or
* the backend op count exceeds the bound *derived from the workload
  manifest*: an intact overlay needs one ``readdir_plus`` per manifest
  directory plus the fused ``remove_tree`` calls (at most one per
  directory before roll-up), so anything above ``2 * n_dirs + slack``
  means per-entry removal leaked back in.  The bound scales with the
  manifest, so any ``REPRO_BENCH_SCALE`` checks the same invariant —
  a fixed threshold tuned at one scale would go vacuous (or spuriously
  red) at another.

Latency is real (small — scales with the tree) so the remote queue
genuinely backs up: pending removals must outlive the walk for the
bulk pass to have anything to collapse; on a virtual clock the eager
unlinks race the rmdirs out of the optimization window and the guard
would flake on scheduling luck.

Scale with REPRO_BENCH_SCALE as usual (CI runs 0.1).

    PYTHONPATH=src REPRO_BENCH_SCALE=0.1 python -m benchmarks.overlay_guard
"""
from __future__ import annotations

import sys

from repro.core import CannyFS, InMemoryBackend, LatencyBackend, LatencyModel

from .workloads import TreeSpec, populate_tree, rmtree_readdir, synth_tree

WORKERS = 4
# beyond one listing per dir + one fused removal per dir, tolerate a few
# stray sync stats plus the removals each worker may claim in the instant
# between a dir's unlinks being admitted and its rmdir collapsing them
OP_SLACK = 4 + 2 * WORKERS


def main() -> int:
    spec = TreeSpec(n_files=200, n_dirs=16).scaled()
    dirs, files = synth_tree(spec)
    # the workload manifest is the source of truth for every bound below
    n_dirs, n_files = len(set(dirs)), len(files)
    entries = n_dirs + n_files
    inner = InMemoryBackend()
    populated = populate_tree(inner, dirs, files)
    if populated != entries:
        print(f"FAIL: populated {populated} entries but the manifest "
              f"lists {entries} — workload generation drifted",
              file=sys.stderr)
        return 1
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.0,
                            seed=3))
    fs = CannyFS(remote, max_inflight=4000, workers=WORKERS)
    rmtree_readdir(fs, "src")
    fs.close()
    st = fs.stats
    snap = inner.snapshot()
    gone = set(snap["files"]) | set(snap["dirs"])
    leftover = [p for p in (*dirs, *(p for p, _ in files)) if p in gone]
    max_ops = 2 * n_dirs + OP_SLACK
    print(f"rmtree_readdir: entries={entries} (dirs={n_dirs} "
          f"files={n_files}) backend_ops={remote.op_count} "
          f"max_ops={max_ops} bulk_removes={st.bulk_removes} "
          f"overlay_readdirs={st.overlay_readdirs} "
          f"elided_ops={st.elided_ops} ledger={len(fs.ledger)}")
    ok = True
    if st.bulk_removes == 0:
        print("FAIL: bulk_removes == 0 — the cross-path bulk-remove pass "
              "did not fire on the overlay-enabled run", file=sys.stderr)
        ok = False
    if remote.op_count > max_ops:
        print(f"FAIL: {remote.op_count} backend ops exceeds the "
              f"manifest-derived bound {max_ops} (one listing per dir + "
              "fused removals) — readdir-driven rmtree left the "
              "optimization window", file=sys.stderr)
        ok = False
    if leftover:
        print(f"FAIL: {len(leftover)} manifest entries survived the "
              "removal", file=sys.stderr)
        ok = False
    if len(fs.ledger):
        print("FAIL: deferred errors during a clean removal", file=sys.stderr)
        ok = False
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
