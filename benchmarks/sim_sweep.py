"""Discrete-event scale sweep: 64 workers, 10k-dir trees, fault storms.

The per-guard benchmarks (``dispatch_guard``, ``overlay_guard``,
``walk_guard``) check *tight* manifest-derived bounds at moderate size.
This sweep is the other axis: drive the full engine stack through the
``SimClock`` at sizes the paced-real harness could never afford — tens
of thousands of modelled roundtrips, a 64-thread pool, seeded fault
storms — and record the simulated schedule in ``BENCH_pr6.json``.
Everything below is a pure function of the manifests and the model
seeds: two same-seed runs (same ``PYTHONHASHSEED``) produce
byte-identical payloads, so the artifact doubles as a regression
fingerprint for the whole dispatch/overlay/prefetch/fault stack.

Phases:

1. **walk10k** — cold walk of a fanout-10 x depth-4 tree (11,111 dirs)
   with the prefetch pipeline on, 64 workers.  At this fanout the
   depth-first walker genuinely races the breadth-first prefetcher —
   the sweep asserts the pipeline still *helps* (fewer roundtrips and a
   shorter makespan than the one-RTT-per-dir ablation floor) and loses
   nothing, rather than the small-tree guard's zero-slack bound.

2. **storm** — extraction of a 1k-dir / 4k-file tree through a
   ``FaultInjectingBackend`` storm: seeded EIO on ~2% of data writes
   plus latency spikes (``delay`` outcome, served on the sim timeline)
   on ~5% of mkdirs, 64 workers.  Every fired write fault must land in
   the ledger as exactly the modelled errno; delay spikes must stretch
   the makespan, not the ledger.

3. **restore_storm** — 64 shards x 1 MiB of sharded checkpoint read
   back *interleaved* (one chunk per shard per pass, the sharded-loader
   access pattern) with the read-ahead plane on, 64 workers: every
   shard keeps its own speculative ``read_vec`` pipeline in flight at
   once.  Not part of ``BENCH_pr6.json`` — ``read_guard`` embeds it in
   ``BENCH_pr7.json`` and enforces its roundtrip/byte checks there.

Sizes honor ``REPRO_BENCH_SCALE`` (CI runs 1.0; use 0.1 for a quick
local smoke).

    PYTHONPATH=src REPRO_BENCH_SCALE=1.0 python -m benchmarks.sim_sweep
"""
from __future__ import annotations

import errno
import json
import sys

from repro.core import (CannyFS, FaultInjectingBackend, FaultPlan, FaultRule,
                        InMemoryBackend, LatencyBackend, LatencyModel,
                        PrefetchPolicy, ReadPolicy, SimClock)

from .workloads import (ColdTreeSpec, RestoreSpec, TreeSpec, cold_walk,
                        extract_tree, populate_cold_tree, populate_restore,
                        restore_read_interleaved, synth_tree)

WORKERS = 64
WALK_BATCH = 64
WALK_META_MS = 40.0
STORM_META_MS = 1.0
WRITE_FAULT_P = 0.02
DELAY_FAULT_P = 0.05
DELAY_S = 0.02


def _load_stats(clock: SimClock) -> dict:
    """Worker-load summary of a finished simulated schedule."""
    busy = {name: s for name, s in clock.thread_seconds().items()
            if name.startswith("cannyfs-w")}
    return {
        "workers_busy": len(busy),
        "busy_total_s": sum(busy.values()),
        "busy_max_s": max(busy.values(), default=0.0),
        "busy_min_s": min(busy.values(), default=0.0),
    }


def walk10k() -> dict:
    spec = ColdTreeSpec(fanout=10, depth=4, files_per_dir=2).scaled()
    inner = InMemoryBackend()
    dirs = populate_cold_tree(inner, spec)
    clock = SimClock()
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=WALK_META_MS, data_ms=WALK_META_MS,
                            jitter_sigma=0.0, seed=6), clock=clock)
    fs = CannyFS(remote, workers=WORKERS, echo_errors=False,
                 prefetch=PrefetchPolicy(adaptive_batch=False,
                                         max_batch=WALK_BATCH))
    visited = cold_walk(fs, spec.root)
    fs.close()
    st = fs.stats
    rtt = WALK_META_MS / 1000.0
    return {
        "spec": {"fanout": spec.fanout, "depth": spec.depth,
                 "files_per_dir": spec.files_per_dir,
                 "n_dirs": len(dirs), "batch": WALK_BATCH},
        "visited_dirs": visited,
        "backend_ops": remote.op_count,
        "ablation_ops": len(dirs),            # one sync RTT per cold dir
        "makespan_virtual_s": clock.makespan(),
        "ablation_makespan_s": len(dirs) * rtt,
        "prefetch_batches": st.prefetch_batches,
        "prefetch_hits": st.prefetch_hits,
        "prefetch_wasted": st.prefetch_wasted,
        "prefetch_cancelled": st.prefetch_cancelled,
        "load": _load_stats(clock),
        "ledger": len(fs.ledger),
    }


def storm() -> dict:
    spec = TreeSpec(n_files=4000, n_dirs=1000, seed=7).scaled()
    dirs, files = synth_tree(spec)
    clock = SimClock()
    lat = LatencyBackend(
        InMemoryBackend(),
        LatencyModel(meta_ms=STORM_META_MS, data_ms=STORM_META_MS,
                     jitter_sigma=0.0, seed=8), clock=clock)
    plan = FaultPlan([
        FaultRule(error="EIO", ops=("write",), probability=WRITE_FAULT_P),
        FaultRule(ops=("mkdir",), probability=DELAY_FAULT_P,
                  outcome="delay", delay_s=DELAY_S),
    ], seed=11)
    chaos = FaultInjectingBackend(lat, plan, clock=clock)
    fs = CannyFS(chaos, max_inflight=4000, workers=WORKERS,
                 echo_errors=False)
    extract_tree(fs, dirs, files)
    fs.close()
    st = fs.stats
    entries = fs.ledger.entries()
    errnos = sorted({errno.errorcode.get(getattr(e.error, "errno", 0) or 0,
                                         "?") for e in entries})
    faulted_ops = sorted({e.kind for e in entries})
    return {
        "spec": {"n_dirs": len(dirs), "n_files": len(files)},
        "engine_ops": st.executed,
        "backend_ops": lat.op_count,
        "makespan_virtual_s": clock.makespan(),
        "steals": st.steals,
        "parks": st.parks,
        "elided_ops": st.elided_ops,
        "ledger": len(fs.ledger),
        "ledger_errnos": errnos,
        "ledger_ops": faulted_ops,
        "load": _load_stats(clock),
    }


def restore_storm() -> dict:
    """64 interleaved 1 MiB shard streams through the read-ahead plane.

    Deterministic like the other phases, but *embedded by read_guard
    into* ``BENCH_pr7.json`` rather than recorded here — BENCH_pr6's
    fingerprint predates the read plane and must stay byte-stable."""
    import math

    spec = RestoreSpec(n_shards=64).scaled()
    inner = InMemoryBackend()
    populate_restore(inner, spec)
    clock = SimClock()
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=WALK_META_MS, data_ms=WALK_META_MS,
                            jitter_sigma=0.0, seed=12), clock=clock)
    window = 512 << 10
    fs = CannyFS(remote, workers=WORKERS, echo_errors=False,
                 readahead=ReadPolicy(adaptive=False, max_bytes=window,
                                      max_files=max(spec.n_shards, 64)))
    nbytes, digest = restore_read_interleaved(fs, spec)
    read_ops = remote.op_count
    fs.close()
    st = fs.stats
    per_shard_off = math.ceil(spec.shard_bytes / spec.chunk)
    return {
        "spec": {"n_shards": spec.n_shards,
                 "shard_bytes": spec.shard_bytes,
                 "chunk": spec.chunk, "window": window,
                 "total_bytes": spec.total_bytes()},
        "workers": WORKERS,
        "bytes": nbytes,
        "sha256": digest,
        "backend_ops": read_ops,
        "ablation_ops": 1 + spec.n_shards * per_shard_off,
        "makespan_virtual_s": clock.makespan(),
        "readahead_windows": st.readahead_windows,
        "readahead_hits": st.readahead_hits,
        "readahead_latched": st.readahead_latched,
        "readahead_wasted": st.readahead_wasted,
        "load": _load_stats(clock),
        "ledger": len(fs.ledger),
    }


def build_report() -> dict:
    # restore_storm() is intentionally absent: read_guard embeds it in
    # BENCH_pr7.json, keeping this artifact's fingerprint unchanged
    return {"workers": WORKERS, "walk10k": walk10k(), "storm": storm()}


def check(report: dict) -> list[str]:
    failures = []
    w, s = report["walk10k"], report["storm"]
    if w["visited_dirs"] != w["spec"]["n_dirs"]:
        failures.append(
            f"walk10k visited {w['visited_dirs']} of "
            f"{w['spec']['n_dirs']} dirs — traversal lost entries at scale")
    if w["ledger"]:
        failures.append(
            f"walk10k left {w['ledger']} deferred errors on a clean walk")
    if w["backend_ops"] >= w["ablation_ops"]:
        failures.append(
            f"walk10k took {w['backend_ops']} roundtrips for "
            f"{w['spec']['n_dirs']} dirs — the pipeline stopped saving "
            "roundtrips at scale")
    if w["makespan_virtual_s"] >= w["ablation_makespan_s"]:
        failures.append(
            f"walk10k makespan {w['makespan_virtual_s']:.1f}s is no better "
            f"than the sequential floor {w['ablation_makespan_s']:.1f}s")
    if w["prefetch_batches"] == 0:
        failures.append("walk10k issued zero vectored prefetch batches")
    if s["ledger"] == 0:
        failures.append(
            "storm fired zero faults — the seeded plan went inert")
    if s["ledger_errnos"] != ["EIO"] or s["ledger_ops"] != ["write"]:
        failures.append(
            f"storm ledger holds {s['ledger_errnos']} on {s['ledger_ops']} "
            "— expected only the planned EIO write faults (delay spikes "
            "must never reach the ledger)")
    if s["load"]["workers_busy"] < 0.9 * report["workers"]:
        failures.append(
            f"storm kept only {s['load']['workers_busy']} of "
            f"{report['workers']} workers busy — dispatch starved the pool")
    return failures


def main(argv=None) -> int:
    report = build_report()
    with open("BENCH_pr6.json", "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    w, s = report["walk10k"], report["storm"]
    print(f"walk10k: dirs={w['spec']['n_dirs']} workers={report['workers']} "
          f"ops={w['backend_ops']} (ablation {w['ablation_ops']}) "
          f"makespan={w['makespan_virtual_s']:.1f}s "
          f"(ablation {w['ablation_makespan_s']:.1f}s) "
          f"batches={w['prefetch_batches']} hits={w['prefetch_hits']}")
    print(f"storm: ops={s['engine_ops']} faults={s['ledger']} "
          f"{s['ledger_errnos']} makespan={s['makespan_virtual_s']:.4f}s "
          f"steals={s['steals']} parks={s['parks']} "
          f"busy={s['load']['workers_busy']}/{report['workers']} workers")
    failures = check(report)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
