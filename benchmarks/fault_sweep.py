"""Chaos harness: the paper's error-path story, measured.

Sweeps injected fault rate x eagerness over an extract + rmtree workload
running under ``run_transaction`` (rollback + resubmit), against the full
decorator stack::

    FaultInjecting(Quota(Latency(InMemory, clock=VirtualClock())))

and emits a JSON table of {fault_rate, eager} -> {wall time, virtual time,
retries, rollbacks, ledger size, injected faults, committed}.  The virtual
clock makes the whole sweep run in seconds of real time while preserving
the latency model's schedule, and the seeded FaultPlan's per-match-index
draws make every cell's decision counts (retries, rollbacks, injected,
committed) reproducible for a given --seed in practice; which *paths*
faulted and timing always vary with worker scheduling, and a capped fire
landing exactly at an attempt boundary can occasionally shift a
retry/rollback count by one.

``--kill-rate`` adds the PR 9 preemption axis: each matching op may be
a seeded ``outcome="kill"`` (``ProcessKilled``, backend dead) instead of
an errno.  Killed cells run with the durability spill armed; on each
preemption the harness revives the storage, mounts fresh and
``CannyFS.resume()``s from the spill before re-executing — the rows gain
kills-fired / resume / ops-redone / convergence columns, where redo and
convergence are measured against a kill-free reference run of the same
cell.

``--tenants N`` adds the PR 10 multi-tenant axis: N tenant views share
ONE engine, the whole storm (fault glob + ``kill_scope``) is confined to
tenant t0's prefix, and the rows gain per-tenant
retries / rollbacks / poison-trips / resumes / ledger / committed
columns plus a digest comparison of every *neighbour* against its own
clean solo run on a private engine — the blast-radius reference cell.
A dirty neighbour (non-empty ledger or digest drift) fails the sweep.

    PYTHONPATH=src python -m benchmarks.fault_sweep --seed 0
    PYTHONPATH=src python -m benchmarks.fault_sweep --seed 0 \\
        --fault-rates 0 0.01 0.05 --quota-frac 1.25 --out sweep.json
    PYTHONPATH=src python -m benchmarks.fault_sweep --seed 0 \\
        --fault-rates 0 --kill-rate 0.002
    PYTHONPATH=src python -m benchmarks.fault_sweep --seed 0 \\
        --fault-rates 0.05 --tenants 4 --kill-rate 0.01
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time

from repro.core import (CannyFS, EagerFlags, FaultInjectingBackend, FaultPlan,
                        FaultRule, InMemoryBackend, LatencyBackend,
                        LatencyModel, ProcessKilled, QuotaBackend, RealClock,
                        VirtualClock, run_transaction)

from .resume_guard import OpCountingBackend, _state_digest
from .workloads import (TreeSpec, synth_tenant_tree, synth_tree,
                        tenant_state_digest)

SPILL_DIR = ".spill"

# ops the chaos plan may fail.  Reads/readdir/stat are excluded so the
# workload's control flow stays valid; unlink/rmdir/remove_tree are included
# to hit the removal phase — with the namespace overlay the rmtree usually
# collapses into fused remove_tree calls, so that is the op a removal-phase
# fault actually lands on — (and occasionally rollback itself, which the
# verification pass absorbs).
CHAOS_OPS = ("mkdir", "create", "write", "unlink", "rmdir", "remove_tree",
             "chmod", "utimens")


def build_stack(*, fault_rate: float, seed: int, quota_bytes: int | None,
                load: float = 1.0, max_failures: int = 3,
                virtual: bool = True, short_rate: float = 0.0,
                spike_rate: float = 0.0, spike_ms: float = 50.0,
                kill_rate: float = 0.0, max_kills: int = 3):
    """-> (top backend, inner InMemoryBackend, counted shim, plan, clock).

    ``short_rate`` adds torn-op faults (writes land a short count instead
    of raising); ``spike_rate``/``spike_ms`` add per-rule latency spikes
    (slow ops, not failed ops — the straggler/backpressure stressor).
    Spikes sleep on the same clock as the latency layer, so virtual runs
    replay them without real stalls.  ``kill_rate`` adds seeded
    ``outcome="kill"`` preemptions (``ProcessKilled``, backend dead until
    ``revive()``), at most ``max_kills`` per cell."""
    inner = InMemoryBackend()
    counted = OpCountingBackend(inner, spill_dir=SPILL_DIR)
    clock = VirtualClock() if virtual else RealClock()
    remote = LatencyBackend(
        counted,
        LatencyModel(meta_ms=1.5, data_ms=1.5, jitter_sigma=0.3,
                     load=load, seed=seed),
        clock=clock)
    stack = remote if quota_bytes is None else QuotaBackend(remote, quota_bytes)
    rules = []
    if fault_rate > 0:
        # max_failures bounds the outage so resubmission can converge —
        # the paper's transient-error model rather than a dead disk
        rules.append(FaultRule(error="EIO", ops=CHAOS_OPS,
                               probability=fault_rate,
                               max_failures=max_failures))
    if short_rate > 0:
        rules.append(FaultRule(outcome="short", ops=("write",),
                               probability=short_rate,
                               max_failures=max_failures))
    if spike_rate > 0:
        rules.append(FaultRule(outcome="delay", ops=CHAOS_OPS,
                               probability=spike_rate,
                               delay_s=spike_ms / 1e3))
    if kill_rate > 0:
        # each firing needs a revive() before the next can land, so
        # max_failures caps the cell's total preemptions
        rules.append(FaultRule(outcome="kill", ops=CHAOS_OPS,
                               probability=kill_rate,
                               max_failures=max_kills))
    plan = FaultPlan(rules, seed=seed)
    top = FaultInjectingBackend(stack, plan, clock=clock)
    return top, inner, counted, plan, clock


def run_chaos_config(*, fault_rate: float, eager: bool, seed: int,
                     quota_frac: float | None = None,
                     spec: TreeSpec | None = None,
                     retries: int = 6, virtual: bool = True,
                     short_rate: float = 0.0, spike_rate: float = 0.0,
                     spike_ms: float = 50.0, kill_rate: float = 0.0,
                     max_kills: int = 3) -> dict:
    """One sweep cell: extract then rmtree, each as a resubmittable
    transaction; returns the measured row.  ``virtual=False`` pays real
    sleeps, making ``wall_s`` the paper-comparable end-to-end time.

    With ``kill_rate`` > 0 the cell runs with the durability spill armed
    and survives up to ``max_kills`` seeded preemptions: each
    ``ProcessKilled`` revives the storage, mounts fresh and resumes from
    the spill before re-executing the interrupted transaction.  Redo and
    convergence columns compare against a kill-free reference run of the
    same cell (one extra run per killed cell)."""
    spec = spec or TreeSpec(n_files=120, n_dirs=12, mean_kb=4.0).scaled()
    reference = None
    if kill_rate > 0:
        reference = run_chaos_config(
            fault_rate=fault_rate, eager=eager, seed=seed,
            quota_frac=quota_frac, spec=spec, retries=retries,
            virtual=virtual, short_rate=short_rate, spike_rate=spike_rate,
            spike_ms=spike_ms)
    dirs, files = synth_tree(spec)
    tree_bytes = sum(len(d) for _, d in files)
    quota_bytes = (int(tree_bytes * quota_frac)
                   if quota_frac is not None else None)
    backend, inner, counted, plan, clock = build_stack(
        fault_rate=fault_rate, seed=seed, quota_bytes=quota_bytes,
        virtual=virtual, short_rate=short_rate, spike_rate=spike_rate,
        spike_ms=spike_ms, kill_rate=kill_rate, max_kills=max_kills)
    flags = EagerFlags() if eager else EagerFlags.all_off()
    workers = 32 if eager else 2

    def mount() -> CannyFS:
        return CannyFS(backend, flags=flags, max_inflight=4000,
                       workers=workers,
                       echo_errors=False)  # chaos is expected; keep quiet

    fs = mount()
    spilled = kill_rate > 0
    if spilled:
        fs.enable_spill(SPILL_DIR)
    kills_fired = resumes = resume_replayed = 0
    acc = {"retries": 0, "rollbacks": 0, "rollback_leftovers": 0,
           "deferred_errors": 0, "fused_writes": 0, "elided_ops": 0,
           "submitted": 0, "ledger": 0, "resume_elided": 0}

    def accumulate(f: CannyFS) -> None:
        st = f.stats
        acc["retries"] += st.retries
        acc["rollbacks"] += st.rollbacks
        acc["rollback_leftovers"] += st.rollback_leftovers
        acc["deferred_errors"] += st.deferred_errors
        acc["fused_writes"] += st.fused_writes
        acc["elided_ops"] += st.elided_ops
        acc["submitted"] += st.submitted
        acc["ledger"] += len(f.ledger)
        acc["resume_elided"] += st.resume_elided_ops

    def run_phase(f: CannyFS, body, name: str) -> CannyFS:
        """run_transaction surviving preemptions: revive + fresh mount +
        resume from the spill, until the phase commits."""
        nonlocal kills_fired, resumes, resume_replayed
        while True:
            try:
                run_transaction(f, body, name=name, retries=retries)
                return f
            except ProcessKilled:
                kills_fired += 1
                if kills_fired > max_kills:
                    raise
                accumulate(f)
                try:
                    f.close()
                except Exception:
                    pass
                backend.revive()
                f = mount()
                rep = f.resume(SPILL_DIR)
                resumes += 1
                resume_replayed += rep.get("replayed", 0)
                if rep.get("committed"):
                    return f   # the kill hit mid-retirement: already done

    def extract(fs):
        for d in dirs:
            fs.makedirs(d)
        now = 0.0
        for path, data in files:
            with fs.open(path, "wb") as f:
                f.write(data)
            fs.utimens(path, now, now)
            fs.chmod(path, 0o644)

    def remove(fs):
        if fs.exists("src"):
            fs.rmtree("src")
        fs.drain()

    t0 = time.monotonic()
    committed = True
    extract_digest = None
    try:
        fs = run_phase(fs, extract, "extract")
        fs.drain()
        extract_digest = _state_digest(inner)
        fs = run_phase(fs, remove, "remove")
    except Exception:  # exhausted retries/kills — report, don't crash
        committed = False
    fs.drain()
    wall_s = time.monotonic() - t0
    accumulate(fs)
    snap = inner.snapshot()

    def data_paths(paths):
        return {p for p in paths
                if p != SPILL_DIR and not p.startswith(SPILL_DIR + "/")}

    clean = (not data_paths(snap["files"])
             and not data_paths(snap["symlinks"])
             and data_paths(snap["dirs"]) == {""})
    converged = None
    if reference is not None:
        converged = bool(committed and clean
                         and extract_digest == reference["extract_digest"])
    row = {
        "fault_rate": fault_rate,
        "eager": eager,
        "quota_frac": quota_frac,
        "seed": seed,
        "wall_s": round(wall_s, 4),
        # which exact ops fault varies with worker scheduling, so virtual_s
        # wobbles ~0.1ms (hence 2 decimals) and deferred_errors' cascade
        # component can vary; decision counts are seed-stable in practice
        # (see module docstring for the attempt-boundary caveat)
        "virtual_s": (round(clock.now(), 2)
                      if isinstance(clock, VirtualClock) else None),
        "retries": acc["retries"],
        "rollbacks": acc["rollbacks"],
        "rollback_leftovers": acc["rollback_leftovers"],
        "ledger_final": acc["ledger"],
        "deferred_errors": acc["deferred_errors"],
        "injected_faults": plan.injected,
        "latency_spikes": plan.delayed,
        "spike_stall_s": round(plan.delay_s_total, 3),
        "fused_writes": acc["fused_writes"],
        "elided_ops": acc["elided_ops"],
        "ops_submitted": acc["submitted"],
        "committed": committed,
        "rolled_back_then_succeeded": committed and acc["rollbacks"] > 0,
        "clean_namespace": clean,
        # -- PR 9 preempt/resume columns ------------------------------
        "kill_rate": kill_rate,
        "kills_fired": kills_fired,
        "resumes": resumes,
        "resume_replayed": resume_replayed,
        "resume_elided_ops": acc["resume_elided"],
        "data_ops_applied": counted.data_ops,
        "extract_digest": extract_digest,
        "ops_redone": (max(0, counted.data_ops
                           - reference["data_ops_applied"])
                       if reference is not None else 0),
        "resume_converged": converged,
    }
    fs.close()
    return row


def run_tenant_chaos(*, n_tenants: int, fault_rate: float, seed: int,
                     kill_rate: float = 0.0, retries: int = 6,
                     max_kills: int = 2) -> dict:
    """One multi-tenant cell: N tenant views share ONE engine; the whole
    storm — the EIO rule's path glob AND the preemption's ``kill_scope``
    — is confined to tenant t0's prefix.  t0 runs with a per-tenant
    durability spill when ``kill_rate`` > 0 and survives preemptions via
    ``Tenant.resume()`` on the live shared engine (no remount: the
    neighbours' windows never close).  Every neighbour is compared
    against its own clean solo run on a private engine — the reference
    cell: empty per-tenant ledger, zero rollbacks/poison trips, and a
    byte-identical final state under its prefix."""
    names = [f"t{i}" for i in range(n_tenants)]
    specs = [TreeSpec(n_files=80, n_dirs=8, mean_kb=2.0,
                      seed=seed + 31 * i).scaled() for i in range(n_tenants)]
    trees = [synth_tenant_tree(specs[i], names[i]) for i in range(n_tenants)]

    def make_body(i):
        dirs, files = trees[i]

        def body(fsv):
            for d in dirs:
                fsv.makedirs(d)
            for path, data in files:
                with fsv.open(path, "wb") as f:
                    f.write(data)
                fsv.utimens(path, 0.0, 0.0)
                fsv.chmod(path, 0o644)
        return body

    # reference cells: each tenant alone, clean, on a private engine
    solo_digest = {}
    for i in range(n_tenants):
        clock = VirtualClock()
        inner = InMemoryBackend()
        remote = LatencyBackend(
            inner, LatencyModel(meta_ms=1.5, data_ms=1.5, jitter_sigma=0.3,
                                seed=seed), clock=clock)
        fs = CannyFS(remote, max_inflight=4000, workers=16,
                     abort_on_error=True, echo_errors=False)
        t = fs.tenant(names[i], names[i])
        run_transaction(t, make_body(i), name=f"{names[i]}-solo",
                        retries=retries)
        fs.close()
        solo_digest[names[i]] = tenant_state_digest(inner, names[i])

    # the stormed concurrent cell
    rules = []
    if fault_rate > 0:
        rules.append(FaultRule(error="EIO", ops=CHAOS_OPS,
                               path_glob="t0/*", probability=fault_rate,
                               max_failures=3))
    if kill_rate > 0:
        rules.append(FaultRule(outcome="kill", ops=CHAOS_OPS,
                               path_glob="t0/*", probability=kill_rate,
                               max_failures=max_kills))
    plan = FaultPlan(rules, seed=seed)
    clock = VirtualClock()
    inner = InMemoryBackend()
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=1.5, data_ms=1.5, jitter_sigma=0.3,
                            seed=seed), clock=clock)
    backend = FaultInjectingBackend(remote, plan, clock=clock,
                                    kill_scope="t0/*")
    fs = CannyFS(backend, max_inflight=4000, workers=16,
                 abort_on_error=True, echo_errors=False)
    tenants = [fs.tenant(n, n) for n in names]
    if kill_rate > 0:
        # per-tenant spill for the stormed tenant only; the dir lives
        # OUTSIDE every prefix so the digests compare data state alone
        tenants[0].enable_spill(".spill-t0")
    kills_fired = 0
    outcomes: dict[str, BaseException | None] = {n: None for n in names}

    def drive(i: int) -> None:
        nonlocal kills_fired
        t, body, name = tenants[i], make_body(i), names[i]
        try:
            if i == 0 and kill_rate > 0:
                while True:
                    try:
                        run_transaction(t, body, name=name, retries=retries)
                        return
                    except ProcessKilled:
                        kills_fired += 1
                        if kills_fired > max_kills:
                            raise
                        backend.revive()
                        t.resume(".spill-t0")
            else:
                run_transaction(t, body, name=name, retries=retries)
        except Exception as e:          # noqa: BLE001 — chaos driver
            outcomes[name] = e

    threads = [threading.Thread(target=drive, args=(i,))
               for i in range(n_tenants)]
    t0 = time.monotonic()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    fs.drain()
    wall_s = time.monotonic() - t0
    st = fs.stats
    per_tenant = {}
    for n in names:
        ts = st.tenants[n]
        digest = tenant_state_digest(inner, n)
        per_tenant[n] = {
            "ops": ts.ops,
            "retries": ts.retries,
            "rollbacks": ts.rollbacks,
            "poison_trips": ts.poison_trips,
            "resumes": ts.resumes,
            "deferred_errors": ts.deferred_errors,
            "ledger": len(fs.ledger.entries_for_tenant(n)),
            "committed": outcomes[n] is None,
            "digest_matches_solo": digest == solo_digest[n],
        }
    fs.close()
    neighbours_clean = all(
        per_tenant[n]["committed"] and per_tenant[n]["ledger"] == 0
        and per_tenant[n]["rollbacks"] == 0
        and per_tenant[n]["poison_trips"] == 0
        and per_tenant[n]["digest_matches_solo"]
        for n in names[1:])
    return {
        "n_tenants": n_tenants,
        "fault_rate": fault_rate,
        "kill_rate": kill_rate,
        "seed": seed,
        "wall_s": round(wall_s, 4),
        "virtual_s": round(clock.now(), 2),
        "injected_faults": plan.injected,
        "kills_fired": kills_fired,
        "tenants": per_tenant,
        "neighbours_clean": neighbours_clean,
    }


def sweep(*, seed: int, fault_rates, eager_modes=(True, False),
          quota_frac: float | None = None, short_rate: float = 0.0,
          spike_rate: float = 0.0, spike_ms: float = 50.0,
          kill_rate: float = 0.0, max_kills: int = 3) -> list[dict]:
    rows = []
    for rate in fault_rates:
        for eager in eager_modes:
            rows.append(run_chaos_config(fault_rate=rate, eager=eager,
                                         seed=seed, quota_frac=quota_frac,
                                         short_rate=short_rate,
                                         spike_rate=spike_rate,
                                         spike_ms=spike_ms,
                                         kill_rate=kill_rate,
                                         max_kills=max_kills))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fault-rates", type=float, nargs="*",
                    default=[0.0, 0.01, 0.05])
    ap.add_argument("--quota-frac", type=float, default=None,
                    help="byte budget as a fraction of the tree size "
                         "(e.g. 1.25); omit for no quota")
    ap.add_argument("--short-rate", type=float, default=0.0,
                    help="probability a write lands torn (short count)")
    ap.add_argument("--spike-rate", type=float, default=0.0,
                    help="probability an op takes a latency spike")
    ap.add_argument("--spike-ms", type=float, default=50.0,
                    help="latency spike length (virtual ms)")
    ap.add_argument("--kill-rate", type=float, default=0.0,
                    help="probability an op is a ProcessKilled preemption "
                         "(arms the durability spill + resume loop)")
    ap.add_argument("--max-kills", type=int, default=3,
                    help="preemption budget per cell")
    ap.add_argument("--tenants", type=int, default=0,
                    help="multi-tenant axis: N tenant views share one "
                         "engine, the storm is confined to t0's prefix, "
                         "neighbours are checked against clean solo runs")
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args()
    if args.tenants > 0:
        rows = [run_tenant_chaos(n_tenants=args.tenants, fault_rate=rate,
                                 seed=args.seed, kill_rate=args.kill_rate,
                                 max_kills=args.max_kills)
                for rate in args.fault_rates]
        doc = {"seed": args.seed, "tenants": args.tenants,
               "tenant_rows": rows}
        text = json.dumps(doc, indent=2)
        if args.out:
            with open(args.out, "w") as f:
                f.write(text + "\n")
        print(text)
        if not all(r["neighbours_clean"] for r in rows):
            print("fault_sweep: error: a storm confined to t0 leaked "
                  "into a neighbour tenant's ledger or final state",
                  file=sys.stderr)
            sys.exit(1)
        print(f"# tenant_sweep_ok cells={len(rows)}", file=sys.stderr)
        return
    rows = sweep(seed=args.seed, fault_rates=args.fault_rates,
                 quota_frac=args.quota_frac, short_rate=args.short_rate,
                 spike_rate=args.spike_rate, spike_ms=args.spike_ms,
                 kill_rate=args.kill_rate, max_kills=args.max_kills)
    doc = {"seed": args.seed, "rows": rows}
    text = json.dumps(doc, indent=2)
    if args.out:  # persist before stdout: a closed pipe must not lose the file
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)
    # sanity for the harness: under faults, at least one cell should show
    # the paper's rollback + successful resubmission (or, on the kill
    # axis, a preemption that resumed and converged).  With an explicit
    # quota the operator may have constructed a can-never-fit experiment —
    # warn but exit 0; without one, non-convergence is a harness bug.
    killed_ok = any(r["kills_fired"] > 0 and r["resume_converged"]
                    for r in rows)
    if any(r["kills_fired"] > 0 and r["resume_converged"] is False
           for r in rows):
        print("fault_sweep: error: a preempted cell resumed without "
              "converging to its kill-free reference", file=sys.stderr)
        sys.exit(1)
    if any(r["injected_faults"] > 0 for r in rows) and \
            not any(r["rolled_back_then_succeeded"] for r in rows) and \
            not killed_ok:
        print("fault_sweep: warning: no config demonstrated rollback + "
              "successful resubmission", file=sys.stderr)
        if args.quota_frac is None:
            sys.exit(1)
    print(f"# sweep_ok cells={len(rows)}", file=sys.stderr)


if __name__ == "__main__":
    main()
