"""Chaos harness: the paper's error-path story, measured.

Sweeps injected fault rate x eagerness over an extract + rmtree workload
running under ``run_transaction`` (rollback + resubmit), against the full
decorator stack::

    FaultInjecting(Quota(Latency(InMemory, clock=VirtualClock())))

and emits a JSON table of {fault_rate, eager} -> {wall time, virtual time,
retries, rollbacks, ledger size, injected faults, committed}.  The virtual
clock makes the whole sweep run in seconds of real time while preserving
the latency model's schedule, and the seeded FaultPlan's per-match-index
draws make every cell's decision counts (retries, rollbacks, injected,
committed) reproducible for a given --seed in practice; which *paths*
faulted and timing always vary with worker scheduling, and a capped fire
landing exactly at an attempt boundary can occasionally shift a
retry/rollback count by one.

    PYTHONPATH=src python -m benchmarks.fault_sweep --seed 0
    PYTHONPATH=src python -m benchmarks.fault_sweep --seed 0 \\
        --fault-rates 0 0.01 0.05 --quota-frac 1.25 --out sweep.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.core import (CannyFS, EagerFlags, FaultInjectingBackend, FaultPlan,
                        FaultRule, InMemoryBackend, LatencyBackend,
                        LatencyModel, QuotaBackend, RealClock, VirtualClock,
                        run_transaction)

from .workloads import TreeSpec, synth_tree

# ops the chaos plan may fail.  Reads/readdir/stat are excluded so the
# workload's control flow stays valid; unlink/rmdir/remove_tree are included
# to hit the removal phase — with the namespace overlay the rmtree usually
# collapses into fused remove_tree calls, so that is the op a removal-phase
# fault actually lands on — (and occasionally rollback itself, which the
# verification pass absorbs).
CHAOS_OPS = ("mkdir", "create", "write", "unlink", "rmdir", "remove_tree",
             "chmod", "utimens")


def build_stack(*, fault_rate: float, seed: int, quota_bytes: int | None,
                load: float = 1.0, max_failures: int = 3,
                virtual: bool = True, short_rate: float = 0.0,
                spike_rate: float = 0.0, spike_ms: float = 50.0):
    """-> (top backend, inner InMemoryBackend, plan, clock).

    ``short_rate`` adds torn-op faults (writes land a short count instead
    of raising); ``spike_rate``/``spike_ms`` add per-rule latency spikes
    (slow ops, not failed ops — the straggler/backpressure stressor).
    Spikes sleep on the same clock as the latency layer, so virtual runs
    replay them without real stalls."""
    inner = InMemoryBackend()
    clock = VirtualClock() if virtual else RealClock()
    remote = LatencyBackend(
        inner,
        LatencyModel(meta_ms=1.5, data_ms=1.5, jitter_sigma=0.3,
                     load=load, seed=seed),
        clock=clock)
    stack = remote if quota_bytes is None else QuotaBackend(remote, quota_bytes)
    rules = []
    if fault_rate > 0:
        # max_failures bounds the outage so resubmission can converge —
        # the paper's transient-error model rather than a dead disk
        rules.append(FaultRule(error="EIO", ops=CHAOS_OPS,
                               probability=fault_rate,
                               max_failures=max_failures))
    if short_rate > 0:
        rules.append(FaultRule(outcome="short", ops=("write",),
                               probability=short_rate,
                               max_failures=max_failures))
    if spike_rate > 0:
        rules.append(FaultRule(outcome="delay", ops=CHAOS_OPS,
                               probability=spike_rate,
                               delay_s=spike_ms / 1e3))
    plan = FaultPlan(rules, seed=seed)
    return FaultInjectingBackend(stack, plan, clock=clock), inner, plan, clock


def run_chaos_config(*, fault_rate: float, eager: bool, seed: int,
                     quota_frac: float | None = None,
                     spec: TreeSpec | None = None,
                     retries: int = 6, virtual: bool = True,
                     short_rate: float = 0.0, spike_rate: float = 0.0,
                     spike_ms: float = 50.0) -> dict:
    """One sweep cell: extract then rmtree, each as a resubmittable
    transaction; returns the measured row.  ``virtual=False`` pays real
    sleeps, making ``wall_s`` the paper-comparable end-to-end time."""
    spec = spec or TreeSpec(n_files=120, n_dirs=12, mean_kb=4.0).scaled()
    dirs, files = synth_tree(spec)
    tree_bytes = sum(len(d) for _, d in files)
    quota_bytes = (int(tree_bytes * quota_frac)
                   if quota_frac is not None else None)
    backend, inner, plan, clock = build_stack(
        fault_rate=fault_rate, seed=seed, quota_bytes=quota_bytes,
        virtual=virtual, short_rate=short_rate, spike_rate=spike_rate,
        spike_ms=spike_ms)
    flags = EagerFlags() if eager else EagerFlags.all_off()
    fs = CannyFS(backend, flags=flags, max_inflight=4000,
                 workers=32 if eager else 2,
                 echo_errors=False)  # chaos is expected; keep stderr quiet

    def extract(fs):
        for d in dirs:
            fs.makedirs(d)
        now = 0.0
        for path, data in files:
            with fs.open(path, "wb") as f:
                f.write(data)
            fs.utimens(path, now, now)
            fs.chmod(path, 0o644)

    def remove(fs):
        if fs.exists("src"):
            fs.rmtree("src")
        fs.drain()

    t0 = time.monotonic()
    committed = True
    try:
        run_transaction(fs, extract, name="extract", retries=retries)
        run_transaction(fs, remove, name="remove", retries=retries)
    except Exception:  # exhausted retries — report, don't crash the sweep
        committed = False
    fs.drain()
    wall_s = time.monotonic() - t0
    st = fs.stats
    row = {
        "fault_rate": fault_rate,
        "eager": eager,
        "quota_frac": quota_frac,
        "seed": seed,
        "wall_s": round(wall_s, 4),
        # which exact ops fault varies with worker scheduling, so virtual_s
        # wobbles ~0.1ms (hence 2 decimals) and deferred_errors' cascade
        # component can vary; decision counts are seed-stable in practice
        # (see module docstring for the attempt-boundary caveat)
        "virtual_s": (round(clock.now(), 2)
                      if isinstance(clock, VirtualClock) else None),
        "retries": st.retries,
        "rollbacks": st.rollbacks,
        "rollback_leftovers": st.rollback_leftovers,
        "ledger_final": len(fs.ledger),
        "deferred_errors": st.deferred_errors,
        "injected_faults": plan.injected,
        "latency_spikes": plan.delayed,
        "spike_stall_s": round(plan.delay_s_total, 3),
        "fused_writes": st.fused_writes,
        "elided_ops": st.elided_ops,
        "ops_submitted": st.submitted,
        "committed": committed,
        "rolled_back_then_succeeded": committed and st.rollbacks > 0,
        "clean_namespace": (lambda s: not s["files"] and not s["symlinks"]
                            and s["dirs"] == {""})(inner.snapshot()),
    }
    fs.close()
    return row


def sweep(*, seed: int, fault_rates, eager_modes=(True, False),
          quota_frac: float | None = None, short_rate: float = 0.0,
          spike_rate: float = 0.0, spike_ms: float = 50.0) -> list[dict]:
    rows = []
    for rate in fault_rates:
        for eager in eager_modes:
            rows.append(run_chaos_config(fault_rate=rate, eager=eager,
                                         seed=seed, quota_frac=quota_frac,
                                         short_rate=short_rate,
                                         spike_rate=spike_rate,
                                         spike_ms=spike_ms))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fault-rates", type=float, nargs="*",
                    default=[0.0, 0.01, 0.05])
    ap.add_argument("--quota-frac", type=float, default=None,
                    help="byte budget as a fraction of the tree size "
                         "(e.g. 1.25); omit for no quota")
    ap.add_argument("--short-rate", type=float, default=0.0,
                    help="probability a write lands torn (short count)")
    ap.add_argument("--spike-rate", type=float, default=0.0,
                    help="probability an op takes a latency spike")
    ap.add_argument("--spike-ms", type=float, default=50.0,
                    help="latency spike length (virtual ms)")
    ap.add_argument("--out", default=None, help="also write JSON here")
    args = ap.parse_args()
    rows = sweep(seed=args.seed, fault_rates=args.fault_rates,
                 quota_frac=args.quota_frac, short_rate=args.short_rate,
                 spike_rate=args.spike_rate, spike_ms=args.spike_ms)
    doc = {"seed": args.seed, "rows": rows}
    text = json.dumps(doc, indent=2)
    if args.out:  # persist before stdout: a closed pipe must not lose the file
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)
    # sanity for the harness: under faults, at least one cell should show
    # the paper's rollback + successful resubmission.  With an explicit
    # quota the operator may have constructed a can-never-fit experiment —
    # warn but exit 0; without one, non-convergence is a harness bug.
    if any(r["injected_faults"] > 0 for r in rows) and \
            not any(r["rolled_back_then_succeeded"] for r in rows):
        print("fault_sweep: warning: no config demonstrated rollback + "
              "successful resubmission", file=sys.stderr)
        if args.quota_frac is None:
            sys.exit(1)
    print(f"# sweep_ok cells={len(rows)}", file=sys.stderr)


if __name__ == "__main__":
    main()
