"""Render EXPERIMENTS.md §Roofline / §Perf tables from the dry-run and
hillclimb JSON records.

    PYTHONPATH=src python -m benchmarks.roofline_report [--md]
"""
import argparse
import glob
import json
from pathlib import Path


def load(pattern):
    out = []
    for p in sorted(glob.glob(pattern)):
        try:
            out.append(json.load(open(p)))
        except json.JSONDecodeError:
            pass
    return out


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def roofline_table(recs, md=False):
    rows = []
    hdr = ["arch", "shape", "mesh", "bottleneck", "compute_ms", "memory_ms",
           "coll_ms", "useful", "MFU-proxy", "peak_mem/dev", "status"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skipped":
            rows.append([r["arch"], r["shape"], r["mesh"], "—", "", "", "",
                         "", "", "", f"skip: {r['reason']}"])
            continue
        if r["status"] != "ok":
            rows.append([r["arch"], r["shape"], r["mesh"], "—", "", "", "",
                         "", "", "", "FAILED"])
            continue
        mem = r.get("memory_analysis", {})
        peak = mem.get("temp_size_in_bytes", 0) + \
            mem.get("argument_size_in_bytes", 0)
        rows.append([
            r["arch"], r["shape"], r["mesh"], r["bottleneck"],
            f"{r['compute_s'] * 1e3:.1f}", f"{r['memory_s'] * 1e3:.1f}",
            f"{r['collective_s'] * 1e3:.1f}",
            f"{r['useful_flops_ratio']:.2f}",
            f"{r['roofline_fraction']:.3f}", fmt_bytes(peak), "ok"])
    if md:
        out = ["| " + " | ".join(hdr) + " |",
               "|" + "---|" * len(hdr)]
        out += ["| " + " | ".join(str(c) for c in row) + " |"
                for row in rows]
        return "\n".join(out)
    w = [max(len(str(r[i])) for r in rows + [hdr]) for i in range(len(hdr))]
    lines = ["  ".join(str(h).ljust(w[i]) for i, h in enumerate(hdr))]
    lines += ["  ".join(str(c).ljust(w[i]) for i, c in enumerate(row))
              for row in rows]
    return "\n".join(lines)


def perf_table(base_recs, perf_recs, md=False):
    base = {(r["arch"], r["shape"]): r for r in base_recs
            if r.get("status") == "ok" and r["mesh"] == "16x16"}
    lines = []
    for r in sorted(perf_recs, key=lambda r: (r["arch"], r["shape"])):
        key = (r["arch"], r["shape"])
        b = base.get(key)
        if r.get("status") != "ok" or b is None:
            lines.append(f"### {key[0]} × {key[1]} — {r.get('variant')}: "
                         f"{r.get('status')} {r.get('error', '')[:200]}")
            continue
        def delta(field):
            if not b[field]:
                return "n/a"
            return f"{(r[field] / b[field] - 1) * 100:+.1f}%"
        lines.append(
            f"### {key[0]} × {key[1]} — variant `{r['variant']}`\n"
            f"*Hypothesis*: {r['hypothesis']}\n\n"
            f"| term | baseline | variant | Δ |\n|---|---|---|---|\n"
            f"| compute_s | {b['compute_s'] * 1e3:.1f}ms | "
            f"{r['compute_s'] * 1e3:.1f}ms | {delta('compute_s')} |\n"
            f"| memory_s | {b['memory_s'] * 1e3:.1f}ms | "
            f"{r['memory_s'] * 1e3:.1f}ms | {delta('memory_s')} |\n"
            f"| collective_s | {b['collective_s'] * 1e3:.1f}ms | "
            f"{r['collective_s'] * 1e3:.1f}ms | {delta('collective_s')} |\n"
            f"| useful_flops | {b['useful_flops_ratio']:.2f} | "
            f"{r['useful_flops_ratio']:.2f} | — |\n"
            f"| step est (max-term) | {b['step_time_s'] * 1e3:.1f}ms | "
            f"{r['step_time_s'] * 1e3:.1f}ms | {delta('step_time_s')} |\n")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--dryrun-dir", default="benchmarks/results/dryrun")
    ap.add_argument("--perf-dir", default="benchmarks/results/perf")
    args = ap.parse_args()
    base = load(f"{args.dryrun_dir}/*.json")
    print("## Roofline (single-pod 16x16, unrolled lowering)\n")
    print(roofline_table(base, md=args.md))
    perf = load(f"{args.perf_dir}/*.json")
    if perf:
        print("\n\n## Perf variants\n")
        print(perf_table(base, perf, md=args.md))


if __name__ == "__main__":
    main()
