"""CI regression guard for the vectored read-side data plane (PR 7).
Emits ``BENCH_pr7.json`` and FAILS (exit 1) when the read-ahead
pipeline regressed.

Default mode is the **discrete-event simulation** (``SimClock``): the
reader and pool workers are actors of a cooperative event-queue
simulation, so whether a speculative window lands before the reader's
next chunk is decided by *modelled* latencies in token order — a pure
function of the manifest and the model's seed.  The guard runs at
``REPRO_BENCH_SCALE=1.0`` in milliseconds of wall time, with **zero
slack** on the roundtrip bounds:

1. **Roundtrip bounds** — streaming a shard of S bytes in C-byte chunks
   through a fixed W-byte read-ahead window must cost exactly
   ``1 + ceil((S - C) / W)`` data roundtrips (one sync miss that
   registers the pipeline, then one vectored ``read_vec`` window per W
   bytes), against the ablation's ``ceil(S / C)`` — checked for the
   checkpoint-restore storm (readdir + per-shard streams, stats warmed
   by the listing) and a single large sequential stream (one cold
   stat).  Both bounds are exact equalities in sim mode.

2. **Virtual-time speedup** — total injected service with read-ahead on
   must beat the ``readahead=False`` ablation by >= 3x.

3. **Byte identity** — on and off runs must produce the same byte count
   and sha256: the buffered plane is an optimization, never a
   semantics change.

The report also embeds ``sim_sweep.restore_storm()`` — the 64-shard
interleaved restore at a scale the paced harness could never afford.

``--paced`` switches to the paced-real smoke (``PacedVirtualClock``:
scaled real sleeps under genuine threading): loose slack, fixed 3x
floor — a non-blocking cross-check, not the blocking guard.

    PYTHONPATH=src REPRO_BENCH_SCALE=1.0 python -m benchmarks.read_guard
    PYTHONPATH=src REPRO_BENCH_SCALE=0.25 python -m benchmarks.read_guard --paced
"""
from __future__ import annotations

import argparse
import json
import math
import sys

from repro.core import (CannyFS, InMemoryBackend, LatencyBackend,
                        LatencyModel, ReadPolicy, SimClock)

from .sim_sweep import restore_storm
from .workloads import (PacedVirtualClock, RestoreSpec, StreamSpec,
                        populate_restore, populate_stream, restore_read,
                        stream_read)

MIN_SPEEDUP = 3.0
WINDOW = 512 << 10   # fixed read-ahead window so the bounds are exact
META_MS = 40.0       # paced mode: 4 ms real per roundtrip; sim: pure virtual
BW_MB_S = 110.0
PACE = 0.1
# paced mode only: tolerate a few duplicate fetches where the reader's
# sync miss raced a window already carrying the same span.  The sim
# schedule has no such races — its slack is zero (exact equality).
OP_SLACK = {"sim": 0, "paced": 8}


def _policy(enabled: bool):
    return (ReadPolicy(adaptive=False, max_bytes=WINDOW) if enabled
            else False)


def _run(populate, body, *, readahead: bool, mode: str) -> dict:
    inner = InMemoryBackend()
    populate(inner)
    clock = SimClock() if mode == "sim" else PacedVirtualClock(pace=PACE)
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=META_MS, data_ms=META_MS,
                            bandwidth_mb_s=BW_MB_S, jitter_sigma=0.0,
                            seed=5), clock=clock)
    fs = CannyFS(remote, workers=8, echo_errors=False,
                 readahead=_policy(readahead))
    nbytes, digest = body(fs)
    read_ops = remote.op_count          # before close() lands stragglers
    fs.close()
    st = fs.stats
    virtual_io = (sum(clock.thread_seconds().values()) if mode == "sim"
                  else clock.now())
    return {
        "bytes": nbytes,
        "sha256": digest,
        "backend_ops_read": read_ops,
        "backend_ops_total": remote.op_count,
        "virtual_io_s": virtual_io,
        "makespan_virtual_s": clock.makespan(),
        "readahead_windows": st.readahead_windows,
        "readahead_hits": st.readahead_hits,
        "readahead_latched": st.readahead_latched,
        "readahead_bytes": st.readahead_bytes,
        "readahead_wasted": st.readahead_wasted,
        "readahead_cancelled": st.readahead_cancelled,
        "ledger": len(fs.ledger),
    }


def _per_stream_ops(size: int, chunk: int) -> tuple[int, int]:
    """(read-ahead-on, ablation) data roundtrips for one sequential
    stream of ``size`` bytes in ``chunk``-byte slices under a fixed
    ``WINDOW``: one registering sync miss + one window per W bytes of
    remainder, vs one sync read per chunk."""
    on = 1 + math.ceil((size - chunk) / WINDOW)
    off = math.ceil(size / chunk)
    return on, off


def build_report(mode: str = "sim") -> dict:
    """Run both read workloads with the plane on and off; return the
    payload (no I/O).  The determinism regression test calls this twice
    and asserts the sim payloads serialize byte-identically."""
    rspec = RestoreSpec().scaled()
    sspec = StreamSpec().scaled()
    r_on = _run(lambda b: populate_restore(b, rspec),
                lambda fs: restore_read(fs, rspec),
                readahead=True, mode=mode)
    r_off = _run(lambda b: populate_restore(b, rspec),
                 lambda fs: restore_read(fs, rspec),
                 readahead=False, mode=mode)
    s_on = _run(lambda b: populate_stream(b, sspec),
                lambda fs: stream_read(fs, sspec),
                readahead=True, mode=mode)
    s_off = _run(lambda b: populate_stream(b, sspec),
                 lambda fs: stream_read(fs, sspec),
                 readahead=False, mode=mode)
    slack = OP_SLACK[mode]
    shard_on, shard_off = _per_stream_ops(rspec.shard_bytes, rspec.chunk)
    stream_on, stream_off = _per_stream_ops(sspec.file_bytes, sspec.chunk)
    report = {
        "mode": mode,
        "window_bytes": WINDOW,
        "restore": {
            "spec": {"n_shards": rspec.n_shards,
                     "shard_bytes": rspec.shard_bytes,
                     "chunk": rspec.chunk,
                     "total_bytes": rspec.total_bytes()},
            "readahead_on": r_on,
            "readahead_off": r_off,
            # 1 readdir_plus + per-shard streams (stats warmed: 0 RTT)
            "max_ops": 1 + rspec.n_shards * shard_on + slack,
            "ablation_ops": 1 + rspec.n_shards * shard_off,
            "speedup_virtual": (r_off["virtual_io_s"] / r_on["virtual_io_s"]
                                if r_on["virtual_io_s"] else 0.0),
            "min_speedup": MIN_SPEEDUP,
        },
        "stream": {
            "spec": {"file_bytes": sspec.file_bytes, "chunk": sspec.chunk},
            "readahead_on": s_on,
            "readahead_off": s_off,
            # 1 cold sync stat + the stream
            "max_ops": 1 + stream_on + slack,
            "ablation_ops": 1 + stream_off,
            "speedup_virtual": (s_off["virtual_io_s"] / s_on["virtual_io_s"]
                                if s_on["virtual_io_s"] else 0.0),
            "min_speedup": MIN_SPEEDUP,
        },
    }
    if mode == "sim":
        # the scale axis: 64 interleaved shard streams, 64 workers —
        # runs on its own SimClock, deterministic like everything above
        report["restore_storm"] = restore_storm()
    return report


def _check_workload(name: str, wl: dict, mode: str) -> list[str]:
    on, off = wl["readahead_on"], wl["readahead_off"]
    failures = []
    if (on["bytes"], on["sha256"]) != (off["bytes"], off["sha256"]):
        failures.append(
            f"{name}: read-ahead returned {on['bytes']}B sha={on['sha256']}"
            f" vs ablation {off['bytes']}B sha={off['sha256']} — the "
            "buffered plane changed the bytes")
    for label, r in (("readahead-on", on), ("readahead-off", off)):
        if r["ledger"]:
            failures.append(
                f"{name}/{label} left {r['ledger']} deferred errors on a "
                "read-only workload")
    if on["backend_ops_total"] > wl["max_ops"]:
        failures.append(
            f"{name}: {on['backend_ops_total']} roundtrips exceeds the "
            f"manifest-derived bound {wl['max_ops']} — the window pipeline "
            "fell behind its consumer")
    if mode == "sim" and on["backend_ops_total"] != wl["max_ops"]:
        failures.append(
            f"{name}: {on['backend_ops_total']} roundtrips != the exact "
            f"sim bound {wl['max_ops']} — the schedule drifted (count the "
            "windows)")
    if on["readahead_windows"] == 0:
        failures.append(
            f"{name}: zero speculative windows issued on a sequential "
            "stream")
    if off["backend_ops_total"] < wl["ablation_ops"]:
        failures.append(
            f"{name}: ablation paid only {off['backend_ops_total']} of "
            f"{wl['ablation_ops']} roundtrips — read-ahead leaked into the "
            "readahead=False run and the speedup is meaningless")
    if wl["speedup_virtual"] < wl["min_speedup"]:
        failures.append(
            f"{name}: virtual I/O improved only "
            f"{wl['speedup_virtual']:.2f}x over the ablation "
            f"(need >= {wl['min_speedup']:.2f}x)")
    return failures


def check(report: dict) -> list[str]:
    """Return the list of FAIL strings for a report (empty == pass)."""
    failures = []
    failures += _check_workload("restore", report["restore"], report["mode"])
    failures += _check_workload("stream", report["stream"], report["mode"])
    storm = report.get("restore_storm")
    if storm is not None:
        if storm["bytes"] != storm["spec"]["total_bytes"]:
            failures.append(
                f"restore_storm read {storm['bytes']} of "
                f"{storm['spec']['total_bytes']} bytes — a shard stream "
                "was truncated")
        if storm["backend_ops"] >= storm["ablation_ops"]:
            failures.append(
                f"restore_storm took {storm['backend_ops']} roundtrips "
                f"(ablation floor {storm['ablation_ops']}) — read-ahead "
                "stopped saving roundtrips at scale")
        if storm["ledger"]:
            failures.append(
                f"restore_storm left {storm['ledger']} deferred errors")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--paced", action="store_true",
                    help="paced-real smoke mode (nondeterministic, loose "
                         "bounds) instead of the simulation")
    args = ap.parse_args(argv)
    mode = "paced" if args.paced else "sim"
    report = build_report(mode)
    with open("BENCH_pr7.json", "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    for name in ("restore", "stream"):
        wl = report[name]
        on, off = wl["readahead_on"], wl["readahead_off"]
        print(f"[{mode}] {name}: on: ops={on['backend_ops_total']} "
              f"(bound {wl['max_ops']}) virtual={on['virtual_io_s']:.2f}s "
              f"makespan={on['makespan_virtual_s']:.2f}s "
              f"windows={on['readahead_windows']} hits={on['readahead_hits']} "
              f"latched={on['readahead_latched']} "
              f"wasted={on['readahead_wasted']}  "
              f"off: ops={off['backend_ops_total']} "
              f"virtual={off['virtual_io_s']:.2f}s  "
              f"speedup={wl['speedup_virtual']:.2f}x "
              f"(floor {wl['min_speedup']:.2f}x)")
    storm = report.get("restore_storm")
    if storm is not None:
        print(f"[sim] restore_storm: shards={storm['spec']['n_shards']} "
              f"workers={storm['workers']} ops={storm['backend_ops']} "
              f"(ablation {storm['ablation_ops']}) "
              f"makespan={storm['makespan_virtual_s']:.2f}s "
              f"windows={storm['readahead_windows']} "
              f"hits={storm['readahead_hits']}")
    failures = check(report)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
