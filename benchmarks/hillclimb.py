"""§Perf hillclimb driver: re-lower chosen (arch × shape) cells under
candidate configurations and record the roofline-term deltas.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell stablelm-12b/train_4k
    PYTHONPATH=src python -m benchmarks.hillclimb --all

Variants are declared per cell with the hypothesis they test; results land
in benchmarks/results/perf/ and EXPERIMENTS.md §Perf reads from there.
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
import argparse
import json
from pathlib import Path

import jax.numpy as jnp

# (variant_name, hypothesis, TrainConfig overrides)
CELLS = {
    "stablelm-12b/train_4k": [
        ("bf16_loss",
         "memory term is dominated by (B,S,V)-sized fp32 loss tensors "
         "(~4e14 B global for V=100352); computing CE in bf16 with fp32 "
         "accumulators halves every vocab-sized pass -> memory term down "
         "20-30%",
         dict(loss_dtype="bfloat16")),
        ("remat_none",
         "dots_no_batch recomputes attention+elementwise in backward; "
         "40 layers of recompute inflate HLO flops ~25%; full residuals "
         "fit for a 12B at batch 16/device -> compute term down, "
         "useful_flops up",
         dict(remat_policy="none")),
        ("bf16_loss+remat_none",
         "the two wins are independent (loss tensors vs layer recompute) "
         "and should compose",
         dict(loss_dtype="bfloat16", remat_policy="none")),
    ],
    "mamba2-130m/train_4k": [
        ("seq_parallel",
         "mamba2 replicates params (no TP) so the model axis idles and "
         "every device holds full (B/dp,S,d_inner) SSD intermediates; "
         "dp_sp shards the residual stream's sequence dim over the 16-way "
         "model axis -> memory term down up to ~16x on SSD tensors at the "
         "price of boundary collectives",
         dict(activation_mode="dp_sp")),
        ("remat_none",
         "130M params leave HBM headroom; dropping remat removes the "
         "recompute pass -> compute term down ~30%",
         dict(remat_policy="none")),
        ("seq_parallel+remat_none",
         "compose both",
         dict(activation_mode="dp_sp", remat_policy="none")),
        ("sp+remat+chunk64",
         "SSD intra-chunk cost is S*Q per head (att matrix Q^2 times S/Q "
         "chunks): halving ssm_chunk 128->64 halves the quadratic-term "
         "flops while only doubling the (tiny) inter-chunk state einsums "
         "-> compute term down up to ~2x on top of sp+remat",
         dict(activation_mode="dp_sp", remat_policy="none",
              _cfg=dict(ssm_chunk=64))),
    ],
    "h2o-danube-3-4b/prefill_32k": [
        ("windowed_blocked_attn",
         "baseline blocked attention scores every q-block against all 32k "
         "keys although the window is 4096 -> 6.4x wasted attention "
         "flops/bytes; slicing K/V to the window per q-block removes it "
         "(REPRO_WINDOWED_ATTN=1 path)",
         dict(_env={"REPRO_WINDOWED_ATTN": "1"})),
    ],
}


def run_variant(arch, shape, name, hypo, overrides, outdir: Path):
    from repro.launch.dryrun import run_cell
    from repro.train.steps import TrainConfig
    env = overrides.pop("_env", {})
    cfg_overrides = overrides.pop("_cfg", None)
    old_env = {}
    for k, v in env.items():
        old_env[k] = os.environ.get(k)
        os.environ[k] = v
    try:
        if "loss_dtype" in overrides:
            overrides["loss_dtype"] = getattr(jnp, overrides["loss_dtype"])
        tc = TrainConfig(**overrides)
        rec = run_cell(arch, shape, multi_pod=False, train_cfg=tc,
                       scan_layers=False, cfg_overrides=cfg_overrides)
    finally:
        for k, v in old_env.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
    rec["variant"] = name
    rec["hypothesis"] = hypo
    path = outdir / f"{arch}__{shape}__{name}.json"
    path.write_text(json.dumps(rec, indent=2))
    print(f"[hillclimb] {arch}/{shape}/{name}: "
          f"compute={rec.get('compute_s', 0) * 1e3:.1f}ms "
          f"memory={rec.get('memory_s', 0) * 1e3:.1f}ms "
          f"collective={rec.get('collective_s', 0) * 1e3:.1f}ms "
          f"useful={rec.get('useful_flops_ratio', 0):.2f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", action="append", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/perf")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    cells = args.cell or (list(CELLS) if args.all else [])
    if not cells:
        ap.error("pass --cell arch/shape or --all")
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    for cell in cells:
        arch, shape = cell.split("/")
        for name, hypo, overrides in CELLS[cell]:
            path = outdir / f"{arch}__{shape}__{name}.json"
            if path.exists() and not args.force:
                print(f"[hillclimb] cached {path}")
                continue
            try:
                run_variant(arch, shape, name, hypo, dict(overrides), outdir)
            except Exception as e:
                path.write_text(json.dumps(
                    {"arch": arch, "shape": shape, "variant": name,
                     "status": "failed", "error": repr(e)}, indent=2))
                print(f"[hillclimb] FAILED {cell}/{name}: {e!r}")


if __name__ == "__main__":
    main()
