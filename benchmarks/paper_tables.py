"""Paper-table reproductions (Table 1, Figs 2–4) + engine ablations.

Each function returns a list of CSV rows (name, us_per_call, derived).
Timing is real wall-clock against the latency-injected remote backend.
"""
from __future__ import annotations

import statistics as st

import numpy as np

from repro.core import CannyFS, EagerFlags, InMemoryBackend

from .workloads import (TreeSpec, bench_scale, extract_then_rm, extract_tree,
                        extract_tree_chunked, fusion_stats,
                        make_remote_backend, remove_tree_manifest,
                        run_extraction, run_removal, synth_tree)


def _summary(name: str, times: list[float], baseline: float | None = None):
    mean = st.mean(times)
    med = st.median(times)
    mx = max(times)
    mn = min(times)
    derived = (f"mean={mean:.2f}s;median={med:.2f}s;min={mn:.2f}s;"
               f"max={mx:.2f}s")
    if baseline:
        derived += f";reduction={100 * (1 - mean / baseline):.1f}%"
    return (name, f"{mean * 1e6:.0f}", derived)


def table1_extraction(replicates: int = 3, loads=(1.0, 4.0)) -> list:
    """Archive extraction, 3 modes (paper Table 1 row 1 / Fig 2).

    Replicates are interleaved across modes (as in the paper) with a fresh
    latency seed per replicate so all modes see the same 'cluster load'."""
    spec = TreeSpec().scaled()
    dirs, files = synth_tree(spec)
    rows = []
    for load in loads:
        times = {m: [] for m in ("cannyfs", "direct", "staging")}
        for r in range(replicates):
            for mode in times:
                times[mode].append(
                    run_extraction(mode, dirs, files, load=load, seed=r))
        base = st.mean(times["direct"])
        for mode in ("cannyfs", "direct", "staging"):
            rows.append(_summary(
                f"extraction/{mode}/load{load:g}", times[mode],
                baseline=None if mode == "direct" else base))
    return rows


def table1_removal(replicates: int = 3, loads=(1.0, 4.0)) -> list:
    """Directory-tree removal, 2 modes (paper Table 1 row 2 / Figs 3–4)."""
    spec = TreeSpec().scaled()
    dirs, files = synth_tree(spec)
    rows = []
    for load in loads:
        times = {m: [] for m in ("cannyfs", "direct")}
        for r in range(replicates):
            for mode in times:
                times[mode].append(
                    run_removal(mode, dirs, files, load=load, seed=100 + r))
        base = st.mean(times["direct"])
        rows.append(_summary(f"removal/cannyfs/load{load:g}",
                             times["cannyfs"], baseline=base))
        rows.append(_summary(f"removal/direct/load{load:g}",
                             times["direct"]))
    return rows


def flag_ablation() -> list:
    """Per-op eagerness flags (paper §2: ~20 individual flags)."""
    spec = TreeSpec(n_files=200, n_dirs=20).scaled()
    dirs, files = synth_tree(spec)
    cases = {
        "all_on": EagerFlags(),
        "no_write": EagerFlags(write=False),
        "no_create": EagerFlags(create=False),
        "no_mkdir": EagerFlags(mkdir=False),
        "no_metadata": EagerFlags(chmod=False, utimens=False),
        "all_off": EagerFlags.all_off(),
    }
    rows = []
    base = None
    for name, flags in cases.items():
        remote = make_remote_backend(load=1.0, seed=7, jitter=0.0)
        import time
        t0 = time.monotonic()
        fs = CannyFS(remote, flags=flags, max_inflight=4000, workers=64)
        extract_tree(fs, dirs, files)
        fs.close()
        t = time.monotonic() - t0
        if name == "all_off":
            base = t
        rows.append((f"flags/{name}", f"{t * 1e6:.0f}", f"time={t:.2f}s"))
    # annotate reductions vs all_off
    rows = [(n, us, f"{d};reduction_vs_sync="
             f"{100 * (1 - float(us) / (base * 1e6)):.1f}%")
            for (n, us, d) in rows]
    return rows


def budget_sweep() -> list:
    """max_inflight budget (paper: default 300, benchmark 4000)."""
    spec = TreeSpec(n_files=300, n_dirs=24).scaled()
    dirs, files = synth_tree(spec)
    rows = []
    for budget in (1, 16, 100, 300, 4000):
        import time
        t0 = time.monotonic()
        fs = CannyFS(make_remote_backend(load=1.0, seed=3, jitter=0.0),
                     max_inflight=budget, workers=64)
        extract_tree(fs, dirs, files)
        fs.close()
        t = time.monotonic() - t0
        rows.append((f"budget/{budget}", f"{t * 1e6:.0f}",
                     f"time={t:.2f}s;max_queue="
                     f"{fs.engine.stats.max_queue_depth}"))
    return rows


def executor_modes() -> list:
    """pool (our worker recycling) vs thread_per_op (the paper's
    implementation; §5.1 lists thread churn as its main overhead)."""
    spec = TreeSpec(n_files=300, n_dirs=24).scaled()
    dirs, files = synth_tree(spec)
    rows = []
    for ex in ("pool", "thread_per_op"):
        ts = []
        for r in range(3):
            ts.append(run_extraction("cannyfs", dirs, files, load=1.0,
                                     seed=50 + r, executor=ex))
        rows.append(_summary(f"executor/{ex}", ts))
    return rows


def rw_switch() -> list:
    """Read-after-write barrier cost (paper §5.1: unzip's symlink handling
    writes a file then immediately reads it back)."""
    import time
    rows = []
    for mode, flags in (("cannyfs", EagerFlags()),
                        ("direct", EagerFlags.all_off())):
        remote = make_remote_backend(load=1.0, seed=11, jitter=0.0)
        fs = CannyFS(remote, flags=flags, max_inflight=4000, workers=64)
        fs.makedirs("links")
        n = max(int(40 * bench_scale()), 8)
        t0 = time.monotonic()
        for i in range(n):
            p = f"links/target_{i}"
            fs.write_file(p, b"payload-%d" % i)
            got = fs.read_file(p)          # forces the per-path barrier
            assert got == b"payload-%d" % i
            fs.symlink(f"target_{i}", f"links/link_{i}")
        fs.close()
        t = time.monotonic() - t0
        rows.append((f"rw_switch/{mode}", f"{t / n * 1e6:.0f}",
                     f"total={t:.2f}s;n={n}"))
    return rows


def fusion_table() -> list:
    """Op-fusion ablation: cannyfs vs cannyfs-nooverlay vs cannyfs-nofusion
    vs direct.

    Three workloads:
    * ``extract`` — chunked (unzip-style) extraction; the coalescer turns
      per-chunk writes into one write_vec per file (fused_writes > 0,
      fewer backend ops, less virtual service time);
    * ``extract_rm`` — extraction and manifest-driven removal in the same
      unobserved window; create+write chains are elided outright
      (elided_ops/bytes_elided > 0) — the transactional rewrite at full
      strength;
    * ``rmtree_readdir`` — readdir-driven removal of a *pre-existing*
      tree (the paper's actual removal benchmark).  Pre-overlay this was
      the engine's worst case: every readdir sealed the chains beneath
      it.  With the overlay on, listings are fused readdir_plus calls,
      stats hit the warmed cache, and the bulk-remove pass collapses the
      unlinks+rmdirs into remove_tree calls (bulk_removes > 0, far fewer
      backend ops than entries); the ``cannyfs-nooverlay`` column is the
      ablation showing exactly what the overlay buys;
    * ``extract_then_rm`` — extraction and *readdir-driven* removal in
      ONE breath: the mkdirs are still pending when the rmdirs arrive,
      so the collapse rests on provisional overlay claims re-verified at
      execution time (PR 4, ROADMAP m).  bulk_removes > 0 here is the
      recovered headline collapse — pre-PR 4 this workload forfeited the
      fused removal entirely.

    Latency is real (slept, small — scale with REPRO_BENCH_SCALE) so the
    remote queue genuinely backs up: that pending backlog is exactly what
    elision rewrites; a virtual clock would drain the queue before the
    removal phase could reach it.  ``service_s`` is the backend's accrued
    service time (``busy_s``: the latency model's virtualized cost of
    every remote call — lower means fewer/cheaper backend ops),
    ``backend_ops`` the number of remote calls, ``wall_s`` real time."""
    import time
    from repro.core import LatencyBackend, LatencyModel

    from .workloads import populate_tree, rmtree_readdir
    spec = TreeSpec(n_files=200, n_dirs=16, mean_kb=24.0).scaled()
    dirs, files = synth_tree(spec)
    # (name, flags, fusion, overlay, workers)
    modes = (("cannyfs", EagerFlags(), True, None, 8),
             ("cannyfs-nooverlay", EagerFlags(), True, False, 8),
             ("cannyfs-nofusion", EagerFlags(), False, None, 8),
             ("direct", EagerFlags.all_off(), False, None, 2))
    workloads = {
        "extract": (None,
                    lambda fs: extract_tree_chunked(fs, dirs, files)),
        "extract_rm": (None,
                       lambda fs: (extract_tree_chunked(fs, dirs, files),
                                   remove_tree_manifest(fs, dirs, files))),
        "rmtree_readdir": (lambda be: populate_tree(be, dirs, files),
                           lambda fs: rmtree_readdir(fs, "src")),
        "extract_then_rm": (None,
                            lambda fs: extract_then_rm(fs, dirs, files)),
    }
    rows = []
    for wname, (prepare, body) in workloads.items():
        for mode, flags, fusion, overlay, workers in modes:
            inner = InMemoryBackend()
            if prepare is not None:
                prepare(inner)   # pre-existing state, bypassing latency
            remote = LatencyBackend(
                inner,
                LatencyModel(meta_ms=3.0, data_ms=3.0, jitter_sigma=0.0,
                             server_slots=8, seed=9))
            t0 = time.monotonic()
            fs = CannyFS(remote, flags=flags, fusion=fusion, overlay=overlay,
                         max_inflight=4000, workers=workers)
            body(fs)
            fs.close()
            wall = time.monotonic() - t0
            fstats = ";".join(f"{k}={v}" for k, v in fusion_stats(fs).items())
            rows.append((f"fusion/{wname}/{mode}",
                         f"{remote.busy_s * 1e6:.0f}",
                         f"service={remote.busy_s:.2f}s;wall={wall:.2f}s;"
                         f"backend_ops={remote.op_count};{fstats}"))
    return rows


def backend_table() -> list:
    """The backend-zoo axis (PR 8): the same-breath ``extract_then_rm``
    workload replayed over three storage media × three engine modes.

    Backends:
    * ``local``        — the NFS-like ``LatencyBackend`` baseline (native
      rename, per-op millisecond latency);
    * ``object_store`` — S3-shaped: whole-object PUT, paginated LIST,
      rename = COPY+DELETE; ``requests`` counts wire requests and is the
      column that matters (a request is money);
    * ``remote``       — SFTP-shaped: every op one high-RTT round-trip,
      vectored ops pay one.

    Modes: ``cannyfs`` (everything on), ``nofusion`` (eager but no
    optimizer — the coalescing/elision ablation), ``direct`` (fully
    synchronous).  ``service_s`` is each backend's own accrued cost
    model time, so columns are comparable *within* a backend row group;
    across backends the interesting figure is how much of the naive
    request stream the engine refuses to send."""
    import time

    from .workloads import (PacedVirtualClock, make_object_store,
                            make_remote_stream)
    spec = TreeSpec(n_files=200, n_dirs=16, mean_kb=24.0).scaled()
    dirs, files = synth_tree(spec)
    backends = {
        "local": lambda: make_remote_backend(jitter=0.0, seed=9,
                                             clock=PacedVirtualClock(0.1)),
        "object_store": lambda: make_object_store(
            clock=PacedVirtualClock(0.1), list_page_size=8),
        "remote": lambda: make_remote_stream(clock=PacedVirtualClock(0.1)),
    }
    modes = (("cannyfs", EagerFlags(), True, 8),
             ("nofusion", EagerFlags(), False, 8),
             ("direct", EagerFlags.all_off(), False, 2))
    rows = []
    for bname, make in backends.items():
        for mode, flags, fusion, workers in modes:
            backend = make()
            t0 = time.monotonic()
            fs = CannyFS(backend, flags=flags, fusion=fusion,
                         max_inflight=4000, workers=workers,
                         echo_errors=False)
            extract_then_rm(fs, dirs, files)
            fs.close()
            wall = time.monotonic() - t0
            st = fs.stats
            derived = (f"service={backend.busy_s:.2f}s;wall={wall:.2f}s;"
                       f"backend_ops={backend.op_count};"
                       f"fused_writes={st.fused_writes};"
                       f"elided_ops={st.elided_ops};"
                       f"bulk_removes={st.bulk_removes};"
                       f"retargeted={st.renames_retargeted}")
            if bname == "object_store":
                derived += (f";requests={backend.request_count};"
                            f"puts={backend.whole_object_puts};"
                            f"rmw={backend.rmw_gets};"
                            f"deletes={backend.requests_by_class['delete']}")
            rows.append((f"backend/{bname}/{mode}",
                         f"{backend.busy_s * 1e6:.0f}", derived))
    return rows


def cold_walk_table() -> list:
    """The speculative metadata-prefetch ablation (PR 5): a cold walk of
    the ``cold_walk`` manifest under cannyfs vs cannyfs-noprefetch vs
    direct.  ``backend_ops`` is the roundtrip count (the pipeline's
    whole point: ~ceil(dirs/batch)+depth instead of one per directory),
    ``service_s`` the latency model's accrued remote cost, and the
    prefetch counters show where the listings came from."""
    import time
    from repro.core import (EagerFlags, InMemoryBackend, LatencyBackend,
                            LatencyModel, PrefetchPolicy)

    from .workloads import ColdTreeSpec, cold_walk, populate_cold_tree
    spec = ColdTreeSpec().scaled()
    modes = (("cannyfs", EagerFlags(), None),
             ("cannyfs-noprefetch", EagerFlags(), False),
             ("direct", EagerFlags.all_off(), False))
    rows = []
    for mode, flags, prefetch in modes:
        inner = InMemoryBackend()
        dirs = populate_cold_tree(inner, spec)
        remote = LatencyBackend(
            inner, LatencyModel(meta_ms=3.0, data_ms=3.0, jitter_sigma=0.0,
                                server_slots=8, seed=9))
        fs = CannyFS(remote, flags=flags, prefetch=prefetch,
                     max_inflight=4000, workers=8)
        t0 = time.monotonic()
        visited = cold_walk(fs, spec.root)
        fs.close()
        wall = time.monotonic() - t0
        st = fs.stats
        assert visited == len(dirs), (mode, visited, len(dirs))
        rows.append((f"cold_walk/{mode}",
                     f"{remote.busy_s * 1e6:.0f}",
                     f"service={remote.busy_s:.2f}s;wall={wall:.2f}s;"
                     f"backend_ops={remote.op_count};dirs={len(dirs)};"
                     f"prefetch_batches={st.prefetch_batches};"
                     f"prefetch_hits={st.prefetch_hits};"
                     f"prefetch_wasted={st.prefetch_wasted}"))
    return rows


def read_ahead_table() -> list:
    """The read-side data-plane ablation (PR 7): the checkpoint-restore
    stream under cannyfs vs cannyfs-noreadahead vs direct.
    ``backend_ops`` is the roundtrip count (one registering sync miss
    plus one vectored ``read_vec`` window per ~W bytes per shard instead
    of one roundtrip per chunk), ``service_s`` the latency model's
    accrued remote cost, and the readahead counters show where the bytes
    came from.  All three modes must return the same checksum."""
    import time
    from repro.core import (EagerFlags, InMemoryBackend, LatencyBackend,
                            LatencyModel, ReadPolicy)

    from .workloads import RestoreSpec, populate_restore, restore_read
    spec = RestoreSpec().scaled()
    modes = (("cannyfs", EagerFlags(),
              ReadPolicy(adaptive=False, max_bytes=512 << 10)),
             ("cannyfs-noreadahead", EagerFlags(), False),
             ("direct", EagerFlags.all_off(), False))
    rows = []
    digests = set()
    for mode, flags, readahead in modes:
        inner = InMemoryBackend()
        populate_restore(inner, spec)
        remote = LatencyBackend(
            inner, LatencyModel(meta_ms=3.0, data_ms=3.0, jitter_sigma=0.0,
                                server_slots=8, seed=9))
        fs = CannyFS(remote, flags=flags, readahead=readahead,
                     max_inflight=4000, workers=8)
        t0 = time.monotonic()
        nbytes, digest = restore_read(fs, spec)
        fs.close()
        wall = time.monotonic() - t0
        st = fs.stats
        digests.add((nbytes, digest))
        rows.append((f"read_ahead/{mode}",
                     f"{remote.busy_s * 1e6:.0f}",
                     f"service={remote.busy_s:.2f}s;wall={wall:.2f}s;"
                     f"backend_ops={remote.op_count};"
                     f"shards={spec.n_shards};bytes={nbytes};"
                     f"ra_windows={st.readahead_windows};"
                     f"ra_hits={st.readahead_hits};"
                     f"ra_latched={st.readahead_latched};"
                     f"ra_wasted={st.readahead_wasted}"))
    assert len(digests) == 1, digests
    return rows


def fault_recovery() -> list:
    """The paper's error-path story (§1/§4): a theoretically possible I/O
    error "will frequently warrant the resubmission of a full job" — so the
    cost of eagerness under faults is (rollback + resubmit) time, which
    should still beat a synchronous run that pays latency on every op.

    Runs the chaos extract+rmtree workload with real (slept) latency so the
    eager-vs-synchronous wall-time gap is measurable, and reports retries
    and injected/deferred error counts per {fault rate x eagerness} cell."""
    from .fault_sweep import run_chaos_config
    rows = []
    for rate in (0.0, 0.01, 0.05):
        for eager in (True, False):
            r = run_chaos_config(fault_rate=rate, eager=eager, seed=0,
                                 virtual=False)
            name = f"faults/rate{rate:g}/{'cannyfs' if eager else 'direct'}"
            rows.append((name, f"{r['wall_s'] * 1e6:.0f}",
                         f"wall={r['wall_s']:.2f}s;"
                         f"retries={r['retries']};"
                         f"rollbacks={r['rollbacks']};"
                         f"injected={r['injected_faults']};"
                         f"deferred={r['deferred_errors']};"
                         f"committed={r['committed']}"))
    return rows


def multi_tenant_table() -> list:
    """Beyond-paper: N batch jobs sharing ONE engine as tenants (PR 10).

    Three cells: the sim fairness leg (Jain index + per-tenant DWRR
    observability from ``EngineStats.tenants``), the storm leg (a fault +
    preemption storm confined to t0's prefix — neighbours must stay
    clean), and a budgeted tenant driven into synchronous EDQUOT/ENOSPC
    (``TenantQuota.usage()``)."""
    from .fault_sweep import run_tenant_chaos
    from .tenant_guard import build_report
    rows = []
    rep = build_report("sim")
    fair = rep["fairness"]
    rows.append(("tenants/fairness",
                 f"{fair['p99_makespan_s'] * 1e6:.0f}",
                 f"jain={fair['jain']:.3f};"
                 f"p99_over_fair={fair['p99_over_fair_share']:.2f};"
                 f"sheds={fair['concurrent']['admission_sheds']}"))
    for name, t in sorted(fair["concurrent"]["tenants"].items()):
        mk = fair["concurrent"]["makespans"][name]
        rows.append((f"tenants/fair/{name}", f"{mk * 1e6:.0f}",
                     f"ops={t['ops']};fused={t['fused']};"
                     f"credits={t['credits_spent']};"
                     f"steals={t['steals_served']};"
                     f"deferred={t['deferred_errors']}"))
    chaos = run_tenant_chaos(n_tenants=4, fault_rate=0.05, seed=0,
                             kill_rate=0.01)
    for name, t in sorted(chaos["tenants"].items()):
        rows.append((f"tenants/storm/{name}", "0",
                     f"retries={t['retries']};rollbacks={t['rollbacks']};"
                     f"poison_trips={t['poison_trips']};"
                     f"resumes={t['resumes']};ledger={t['ledger']};"
                     f"committed={t['committed']};"
                     f"solo_identical={t['digest_matches_solo']}"))
    rows.append(("tenants/storm", "0",
                 f"injected={chaos['injected_faults']};"
                 f"kills={chaos['kills_fired']};"
                 f"neighbours_clean={chaos['neighbours_clean']}"))
    # budget cell: a tenant hitting its synchronous byte + inode budget
    from repro.core import TenantQuota
    fs = CannyFS(InMemoryBackend())
    t = fs.tenant("q", "q", quota=TenantQuota(budget_bytes=16 << 10,
                                              max_inodes=24))
    t.mkdir("q")
    admitted = denied = 0
    for i in range(40):
        try:
            with t.open(f"q/f{i:03d}.bin", "wb") as f:
                f.write(b"x" * 1024)
            admitted += 1
        except OSError:
            denied += 1
    fs.drain()
    u = t.quota.usage()
    fs.close()
    rows.append(("tenants/quota", "0",
                 f"admitted={admitted};denied={denied};"
                 f"bytes_used={u['bytes_used']};"
                 f"inodes_used={u['inodes_used']};"
                 f"edquot={u['edquot_count']};enospc={u['enospc_count']}"))
    return rows


def variance_under_load(replicates: int = 6) -> list:
    """Fig 2/4's variance story: time spread under jittery load."""
    spec = TreeSpec(n_files=250, n_dirs=20).scaled()
    dirs, files = synth_tree(spec)
    rows = []
    for mode in ("cannyfs", "direct"):
        ts = [run_extraction(mode, dirs, files, load=float(np.random.default_rng(r).uniform(1, 6)),
                             seed=200 + r)
              for r in range(replicates)]
        import statistics as st
        rows.append((f"variance/{mode}", f"{st.mean(ts) * 1e6:.0f}",
                     f"mean={st.mean(ts):.2f}s;stdev={st.stdev(ts):.2f}s;"
                     f"max={max(ts):.2f}s;min={min(ts):.2f}s"))
    return rows
