"""Inject the generated roofline/perf tables into EXPERIMENTS.md
(replaces the <!-- ROOFLINE_TABLE --> / <!-- PERF_TABLE --> markers).

    PYTHONPATH=src python -m benchmarks.update_experiments
"""
from pathlib import Path

from .roofline_report import load, perf_table, roofline_table

EXP = Path("EXPERIMENTS.md")


def main():
    base = load("benchmarks/results/dryrun/*.json")
    scanned = load("benchmarks/results/dryrun_scanned/*.json")
    perf = load("benchmarks/results/perf/*.json")
    text = EXP.read_text()

    table = roofline_table(base, md=True) if base else "(no records)"
    n_ok = sum(1 for r in base if r.get("status") == "ok")
    n_sk = sum(1 for r in base if r.get("status") == "skipped")
    caption = (f"\n{n_ok} cells analysed (+{n_sk} recorded skips), "
               "unrolled lowering, single-pod.  The scanned production "
               f"lowering additionally compiles "
               f"{sum(1 for r in scanned if r.get('status') == 'ok')} cells "
               "across both meshes (dryrun_scanned/).\n")
    marker = "<!-- ROOFLINE_TABLE -->"
    if marker in text:
        text = text.replace(marker, marker + "\n" + caption + "\n" + table)
    ptable = perf_table(base, perf, md=True) if perf else ""
    pmarker = "<!-- PERF_TABLE -->"
    if pmarker in text and ptable:
        text = text.replace(pmarker, pmarker + "\n\n" + ptable)
    EXP.write_text(text)
    print(f"updated EXPERIMENTS.md: {n_ok} roofline rows, "
          f"{len(perf)} perf variants")


if __name__ == "__main__":
    main()
