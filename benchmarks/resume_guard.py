"""CI regression guard for the durability spill + crash-resume path
(PR 9).  Emits ``BENCH_pr9.json`` and FAILS (exit 1) when a preempted
transaction stops resuming cheaply — or stops resuming *correctly*.

Default mode is the **discrete-event simulation** (``SimClock``) at
``REPRO_BENCH_SCALE=1.0``: the extractor, the pool workers and the
spill's speculative flush lane are all actors of one event-queue
simulation, so *which* journal records land before the injected kill is
a pure function of the manifest and the fault plan — same seed, same
``PYTHONHASHSEED``, byte-identical payload.

The workload is the paper's transactional batch job: extract a
kernel-shaped tree (mkdir sweep + create/write/chmod per file), then
``rmtree`` one subtree — run under ``run_transaction`` with the spill
armed.  The guard preempts it with ``FaultRule(outcome="kill")`` at
seeded points (15% / 50% / 85% of the from-scratch mutating-call
stream), then mounts FRESH state, ``CannyFS.resume()``s from the spill
and re-executes the same body.  Three properties gate CI:

1. **Convergence** — the preempted-and-resumed run's final backend
   state (paths, bytes, modes, links; the spill dir excluded) must
   digest-match the uninterrupted baseline, at every kill point.

2. **Bounded redo** — total *data-root* mutating backend ops across
   the killed attempt plus the resume may exceed the from-scratch cost
   by at most ``MAX_REDONE_FRACTION`` (25%): the resume re-proves the
   window from the journal and elides/diverts provably-durable ops
   instead of re-extracting the tree.

3. **Resume did the claimed work** — mid/late kills must show replayed
   journal events and elided re-run ops (> 0), so the bound cannot be
   met vacuously by a no-op spill.

``--paced`` switches to the paced-real smoke (``PacedVirtualClock``:
scaled real sleeps under genuine threading): the convergence and redo
bounds still hold — resume correctness is schedule-independent — but
the payload is not byte-stable, so it stays non-blocking.

    PYTHONPATH=src PYTHONHASHSEED=0 REPRO_BENCH_SCALE=1.0 python -m benchmarks.resume_guard
    PYTHONPATH=src REPRO_BENCH_SCALE=0.25 python -m benchmarks.resume_guard --paced
"""
from __future__ import annotations

import argparse
import hashlib
import json
import sys

from repro.core import (CannyFS, FaultInjectingBackend, FaultPlan, FaultRule,
                        InMemoryBackend, LatencyBackend, LatencyModel,
                        ProcessKilled, SimClock, run_transaction)

from .workloads import PacedVirtualClock, TreeSpec, bench_scale, synth_tree

MAX_REDONE_FRACTION = 0.25
KILL_FRACTIONS = (0.15, 0.50, 0.85)   # of the from-scratch mutating calls
SPILL_DIR = ".spill"
FLUSH_RECORDS = 8     # small chunks: the uncertainty window stays tight
META_MS = 1.5         # NFS-shaped roundtrips, jitter pinned to zero
BW_MB_S = 110.0
PACE = 0.05
WORKERS = 8
RM_TARGET = 0.12      # aim the rmtree at ~12% of the extracted files

MUTATING_OPS = ("mkdir", "create", "write_at", "write_vec", "unlink",
                "rmdir", "rename", "remove_tree", "chmod", "truncate")
# the fault plan's matching kinds: write_at/write_vec both gate as "write"
GATE_KINDS = ("mkdir", "create", "write", "unlink", "rmdir", "rename",
              "remove_tree", "chmod", "truncate")


class OpCountingBackend:
    """Innermost counting shim: tallies mutating ops that actually
    *applied* to storage, split data-root vs spill-dir.  Sits below the
    fault injector, so a killed (never-applied) op is not counted —
    exactly the ledger the redo bound is stated over."""

    def __init__(self, inner, spill_dir: str = SPILL_DIR):
        self._inner = inner
        self._spill_prefix = spill_dir
        self.data_ops = 0
        self.spill_ops = 0
        self.per_op: dict[str, int] = {}
        for name in MUTATING_OPS:
            if hasattr(inner, name):
                setattr(self, name, self._wrap(name))

    def _wrap(self, name):
        fn = getattr(self._inner, name)

        def call(path, *args, **kwargs):
            out = fn(path, *args, **kwargs)
            p = str(path)
            if p == self._spill_prefix or \
                    p.startswith(self._spill_prefix + "/"):
                self.spill_ops += 1
            else:
                self.data_ops += 1
                self.per_op[name] = self.per_op.get(name, 0) + 1
            return out

        return call

    def __getattr__(self, name):
        return getattr(self._inner, name)


def _state_digest(mem: InMemoryBackend) -> str:
    """Canonical digest of the backend image (paths, bytes, modes,
    symlink targets), spill dir excluded — two runs converged iff their
    digests match."""
    def visible(p: str) -> bool:
        return not (p == SPILL_DIR or p.startswith(SPILL_DIR + "/"))

    snap = mem.snapshot()
    lines = []
    for p in sorted(snap["files"]):
        if visible(p):
            lines.append(f"F {p} {mem.stat(p).mode:o} "
                         f"{hashlib.sha256(snap['files'][p]).hexdigest()}")
    for p in sorted(snap["dirs"]):
        if visible(p):
            lines.append(f"D {p} {mem.stat(p).mode:o}")
    for p in sorted(snap["symlinks"]):
        if visible(p):
            lines.append(f"L {p} {snap['symlinks'][p]}")
    return hashlib.sha256("\n".join(lines).encode()).hexdigest()


def _rm_root(dirs, files) -> str:
    """The subtree the job removes: the directory whose recursive file
    share is closest to ``RM_TARGET`` — deterministic in the manifest."""
    def share(d: str) -> float:
        pre = d + "/"
        return sum(1 for p, _ in files if p.startswith(pre)) / len(files)

    candidates = [d for d in dirs if d != "src"]
    return min(candidates, key=lambda d: (abs(share(d) - RM_TARGET), d))


def _make_body(dirs, files, rm_root):
    """extract + rmtree with FIXED arguments — re-executed verbatim on
    resume, so elision/diversion can prove op identity."""
    def body(fs: CannyFS):
        for d in dirs:
            fs.makedirs(d)
        for path, data in files:
            fs.write_file(path, data)
            fs.chmod(path, 0o644)
        fs.rmtree(rm_root)
    return body


def _mount(counting, mode: str, plan: FaultPlan | None):
    clock = SimClock() if mode == "sim" else PacedVirtualClock(pace=PACE)
    remote = LatencyBackend(
        counting, LatencyModel(meta_ms=META_MS, data_ms=META_MS,
                               bandwidth_mb_s=BW_MB_S, jitter_sigma=0.0,
                               seed=5), clock=clock)
    backend = remote if plan is None else \
        FaultInjectingBackend(remote, plan, clock=clock)
    fs = CannyFS(backend, max_inflight=4000, workers=WORKERS,
                 echo_errors=False)
    return fs, clock


def _baseline(body, mode: str) -> dict:
    mem = InMemoryBackend()
    counting = OpCountingBackend(mem)
    fs, clock = _mount(counting, mode, FaultPlan([], seed=13))
    fs.enable_spill(SPILL_DIR, flush_records=FLUSH_RECORDS)
    run_transaction(fs, body, name="extract", retries=0)
    fs.close()
    return {
        "data_ops": counting.data_ops,
        "spill_ops": counting.spill_ops,
        "mutating_calls": counting.data_ops + counting.spill_ops,
        "per_op": dict(sorted(counting.per_op.items())),
        "makespan_virtual_s": clock.makespan(),
        "spill_records": fs.stats.spill_records,
        "spill_cuts": fs.stats.spill_cuts,
        "ledger": len(fs.ledger),
        "state_digest": _state_digest(mem),
    }


def _preempted(body, mode: str, fraction: float, kill_after: int) -> dict:
    mem = InMemoryBackend()
    counting = OpCountingBackend(mem)
    plan = FaultPlan([FaultRule(ops=GATE_KINDS, after_count=kill_after,
                                max_failures=1, outcome="kill")], seed=13)
    fs, clock = _mount(counting, mode, plan)
    fs.enable_spill(SPILL_DIR, flush_records=FLUSH_RECORDS)
    killed = False
    try:
        run_transaction(fs, body, name="extract", retries=0)
    except ProcessKilled:
        killed = True
    try:
        fs.close()
    except Exception:
        pass
    killrun_ops = counting.data_ops

    # fresh mount over the survived state: dropping the fault wrapper IS
    # the revive (the dead flag lived on it), the spill dir persists
    fs2, clock2 = _mount(counting, mode, None)
    report = fs2.resume(SPILL_DIR, flush_records=FLUSH_RECORDS)
    committed_early = bool(report.get("committed"))
    if not committed_early:
        run_transaction(fs2, body, name="extract", retries=0)
    fs2.close()
    resume_ops = counting.data_ops - killrun_ops
    return {
        "fraction": fraction,
        "kill_after": kill_after,
        "killed": killed,
        "committed_early": committed_early,
        "killrun_data_ops": killrun_ops,
        "resume_data_ops": resume_ops,
        "spill_ops": counting.spill_ops,
        "resume_records": report.get("records", 0),
        "resume_replayed": report.get("replayed", 0),
        "resume_repairs": report.get("repairs", 0),
        "resume_elided_ops": fs2.stats.resume_elided_ops,
        "resume_makespan_virtual_s": clock2.makespan(),
        "ledger": len(fs2.ledger),
        "state_digest": _state_digest(mem),
    }


def build_report(mode: str = "sim") -> dict:
    spec = TreeSpec(n_files=900, n_dirs=90, seed=17).scaled()
    dirs, files = synth_tree(spec)
    rm_root = _rm_root(dirs, files)
    body = _make_body(dirs, files, rm_root)
    base = _baseline(body, mode)
    preemptions = [
        _preempted(body, mode, f,
                   max(1, int(base["mutating_calls"] * f)))
        for f in KILL_FRACTIONS
    ]
    return {
        "mode": mode,
        "spec": {"n_dirs": len(dirs), "n_files": len(files),
                 "rm_root": rm_root,
                 "rm_files": sum(1 for p, _ in files
                                 if p.startswith(rm_root + "/"))},
        "flush_records": FLUSH_RECORDS,
        "max_redone_fraction": MAX_REDONE_FRACTION,
        "baseline": base,
        "preemptions": preemptions,
    }


def _redone(pre: dict, base: dict) -> int:
    return max(0, pre["killrun_data_ops"] + pre["resume_data_ops"]
               - base["data_ops"])


def check(report: dict) -> list[str]:
    """Return the list of FAIL strings for a report (empty == pass)."""
    failures = []
    base = report["baseline"]
    if base["ledger"]:
        failures.append(
            f"baseline left {base['ledger']} deferred errors on a "
            "fault-free run")
    if base["spill_records"] == 0 or base["spill_cuts"] == 0:
        failures.append(
            "baseline spilled no records/cuts — the durability journal "
            "never engaged and every downstream bound is vacuous")
    budget = int(MAX_REDONE_FRACTION * base["data_ops"])
    for pre in report["preemptions"]:
        tag = f"kill@{pre['fraction']:.0%}"
        if not pre["killed"]:
            failures.append(
                f"{tag}: the injected preemption never fired "
                f"(after_count={pre['kill_after']})")
            continue
        if pre["state_digest"] != base["state_digest"]:
            failures.append(
                f"{tag}: resumed state digest {pre['state_digest'][:12]} "
                f"!= baseline {base['state_digest'][:12]} — recovery did "
                "not converge to the uninterrupted run")
        redone = _redone(pre, base)
        if redone > budget:
            failures.append(
                f"{tag}: {redone} data ops redone exceeds the "
                f"{MAX_REDONE_FRACTION:.0%} budget ({budget} of "
                f"{base['data_ops']}) — resume stopped eliding durable "
                "work")
        if pre["committed_early"]:
            continue
        if pre["fraction"] >= 0.5 and pre["resume_replayed"] == 0:
            failures.append(
                f"{tag}: resume replayed zero journal events after a "
                "mid-run kill — the overlay delta was re-walked, not "
                "re-proved")
        if pre["fraction"] >= 0.5 and pre["resume_elided_ops"] == 0:
            failures.append(
                f"{tag}: the re-run elided zero provably-durable ops — "
                "the redo bound is holding by accident")
        if pre["ledger"]:
            failures.append(
                f"{tag}: resume left {pre['ledger']} deferred errors")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--paced", action="store_true",
                    help="paced-real smoke mode (nondeterministic, "
                         "non-blocking) instead of the simulation")
    args = ap.parse_args(argv)
    mode = "paced" if args.paced else "sim"
    report = build_report(mode)
    with open("BENCH_pr9.json", "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    base = report["baseline"]
    print(f"[{mode}] baseline: data_ops={base['data_ops']} "
          f"spill_ops={base['spill_ops']} "
          f"records={base['spill_records']} cuts={base['spill_cuts']} "
          f"makespan={base['makespan_virtual_s']:.2f}s "
          f"scale={bench_scale()}")
    for pre in report["preemptions"]:
        redone = _redone(pre, base)
        print(f"[{mode}] kill@{pre['fraction']:.0%} "
              f"(after {pre['kill_after']} calls): "
              f"killrun={pre['killrun_data_ops']} "
              f"resume={pre['resume_data_ops']} "
              f"redone={redone} "
              f"(budget {int(MAX_REDONE_FRACTION * base['data_ops'])}) "
              f"replayed={pre['resume_replayed']} "
              f"elided={pre['resume_elided_ops']} "
              f"repairs={pre['resume_repairs']} "
              f"converged={pre['state_digest'] == base['state_digest']}"
              + (" committed-early" if pre["committed_early"] else ""))
    failures = check(report)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
